//! Compare curvature approximations numerically (the question behind
//! the paper's Sec. 4: "MC estimates give similar progress to their
//! more accurate counterparts").
//!
//! On one 3c3d batch, computes the exact GGN diagonal, its MC estimate,
//! and the diagonals implied by KFAC/KFLR's Kronecker structure, then
//! reports cosine similarity and median relative error vs the exact
//! diagonal, per layer.
//!
//! Run: `cargo run --release --example curvature_compare`

use anyhow::Result;
use backpack_rs::coordinator::train::{build_inputs, init_params};
use backpack_rs::data::{DatasetSpec, Synthetic};
use backpack_rs::runtime::{Outputs, Runtime, Tensor};

fn cosine(a: &[f32], b: &[f32]) -> f32 {
    let dot: f32 = a.iter().zip(b).map(|(x, y)| x * y).sum();
    let na: f32 = a.iter().map(|x| x * x).sum::<f32>().sqrt();
    let nb: f32 = b.iter().map(|x| x * x).sum::<f32>().sqrt();
    dot / (na * nb).max(1e-12)
}

/// diag(A ⊗ B) for the weight block: outer(diag(B), diag(A)) flattened.
fn kron_diag(out: &Outputs, prefix: &str, layer: &str) -> Result<Vec<f32>> {
    let a = out.get(&format!("{prefix}/{layer}/A"))?;
    let b = out.get(&format!("{prefix}/{layer}/B"))?;
    let (da, db) = (a.shape[0], b.shape[0]);
    let av = a.f32s()?;
    let bv = b.f32s()?;
    let mut d = Vec::with_capacity(da * db);
    for i in 0..db {
        for j in 0..da {
            d.push(bv[i * db + i] * av[j * da + j]);
        }
    }
    Ok(d)
}

fn main() -> Result<()> {
    let rt = Runtime::open_default()?;
    let ds = Synthetic::new(DatasetSpec::by_name("cifar10").unwrap(), 3);
    let idx: Vec<usize> = (0..32).collect();
    let (xv, yv) = ds.batch(0, &idx);

    let mut results: Vec<(String, Outputs)> = Vec::new();
    for name in [
        "3c3d_diag_ggn_n32",
        "3c3d_diag_ggn_mc_n32",
        "3c3d_kfac_n32",
        "3c3d_kflr_n32",
    ] {
        let exe = rt.load(name)?;
        let x = Tensor::from_f32(&[32, 3, 32, 32], xv.clone());
        let y = Tensor::from_i32(&[32], yv.clone());
        let params = init_params(&exe.spec, 0);
        let key = exe.spec.has_key.then_some([9u32, 9u32]);
        let out = exe.run(&build_inputs(&params, x, y, key))?;
        results.push((name.to_string(), out));
        println!("computed {name}");
    }
    let exact = &results[0].1;

    println!(
        "\n{:28} {:>10} {:>10}",
        "curvature (weight blocks)", "cosine", "med.relerr"
    );
    // layer indices of parameterized layers in 3c3d
    for layer in ["0", "3", "6", "10", "12", "14"] {
        let d_exact = exact.get(&format!("diag_ggn/{layer}/w"))?.f32s()?;
        let mc = results[1]
            .1
            .get(&format!("diag_ggn_mc/{layer}/w"))?
            .f32s()?
            .to_vec();
        let kfac = kron_diag(&results[2].1, "kfac", layer)?;
        let kflr = kron_diag(&results[3].1, "kflr", layer)?;
        for (label, approx) in [
            (format!("layer {layer} DiagGGN-MC"), mc),
            (format!("layer {layer} KFAC-diag"), kfac),
            (format!("layer {layer} KFLR-diag"), kflr),
        ] {
            let mut rel: Vec<f32> = d_exact
                .iter()
                .zip(&approx)
                .map(|(e, a)| (a - e).abs() / e.abs().max(1e-12))
                .collect();
            rel.sort_by(|a, b| a.partial_cmp(b).unwrap());
            println!(
                "{label:28} {:>10.4} {:>10.3}",
                cosine(d_exact, &approx),
                rel[rel.len() / 2]
            );
        }
    }
    println!(
        "\nExpected pattern (paper Sec. 3-4): the MC diagonal tracks the \
         exact one\nup to sampling noise; Kronecker diagonals are \
         coarser but directionally\naligned -- and the MC variants are \
         far cheaper to compute (Fig. 6/8)."
    );
    Ok(())
}
