//! Gradient-noise diagnostics from first-order extensions -- the
//! motivating application of the paper's introduction (Balles et al.
//! 2017; Mahsereci & Hennig 2017): use the within-batch gradient
//! variance to estimate the gradient signal-to-noise ratio and a
//! critical batch size, during training, at almost no extra cost.
//!
//! For each parameter block: SNR = |g|² / (tr(Σ)/N) and the
//! gradient-noise-scale estimate B_crit ≈ tr(Σ) / |g|² (simple
//! variant of McCandlish et al.'s B_simple with our variance output).
//!
//! Run: `cargo run --release --example noise_scale`

use anyhow::Result;
use backpack_rs::coordinator::train::{build_inputs, init_params};
use backpack_rs::data::Batcher;
use backpack_rs::optim::{self, Hyper, NamedParam};
use backpack_rs::runtime::Runtime;

fn main() -> Result<()> {
    let rt = Runtime::open_default()?;
    // 3c3d with variance + batch_l2 in the same backward pass.
    let exe = rt.load("3c3d_batch_l2+variance_n32")?;
    let spec = &exe.spec;
    let n = spec.batch_size as f32;

    let problem =
        backpack_rs::coordinator::problems::by_name("cifar10_3c3d")?;
    let dataset = problem.make_dataset(0xDA7A5E_u64)?;
    let mut batcher = Batcher::new(dataset, spec.batch_size, 1);
    let mut params: Vec<NamedParam> = init_params(spec, 1);
    // Train with plain SGD while monitoring noise (the artifact also
    // returns the gradient -- one pass does everything).
    let mut opt = optim::build(
        "sgd", Hyper { lr: 0.05, damping: 0.0, l2: 0.0 }, 1)?;

    println!(
        "{:>5} {:>10} {:>12} {:>12} {:>12}",
        "step", "loss", "|g|^2", "tr(Var)", "B_crit"
    );
    for step in 0..60 {
        let (x, y) = batcher.next_batch();
        let out = exe.run(&build_inputs(&params, x, y, None))?;
        if step % 10 == 0 {
            let mut gsq_total = 0.0f64;
            let mut var_total = 0.0f64;
            for p in &params {
                let g = out.get(&p.under("grad"))?.f32s()?;
                let v = out.get(&p.under("variance"))?.f32s()?;
                gsq_total += g.iter().map(|x| (*x as f64).powi(2)).sum::<f64>();
                var_total += v.iter().map(|x| *x as f64).sum::<f64>();
            }
            // variance output is the per-sample population variance;
            // the mini-batch mean gradient has covariance Var/N.
            let bcrit = var_total / gsq_total.max(1e-24);
            println!(
                "{:>5} {:>10.4} {:>12.4e} {:>12.4e} {:>12.1}",
                step,
                out.loss()?,
                gsq_total,
                var_total,
                bcrit
            );
            let _ = n;
        }
        opt.step(&mut params, &out)?;
    }
    println!(
        "\nInterpretation: while |g|² shrinks as SGD converges, tr(Var) \
         stays O(1),\nso the implied critical batch size B_crit grows -- \
         the classic signal for\nlearning-rate/batch-size adaptation \
         the paper cites (Balles et al. 2017)."
    );
    Ok(())
}
