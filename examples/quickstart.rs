//! Quickstart: the Rust analogue of the paper's Fig. 1, on the
//! default native backend — no Python, no artifacts, no features.
//!
//! PyTorch+BackPACK:
//! ```python
//! model    = extend(Linear(784, 10))
//! lossfunc = extend(CrossEntropyLoss())
//! with backpack(Variance()):
//!     loss = lossfunc(model(X), y); loss.backward()
//! print(param.grad, param.var)
//! ```
//!
//! Here the backend synthesizes the extended-backward graph from its
//! artifact name and runs it in pure Rust: one `run` returns the
//! gradient AND the variance (plus the other first-order quantities)
//! in the same pass. Every quantity is an `Extension` module behind
//! the `backend/extensions/` registry — the same snippet works for a
//! user-defined quantity after `NativeBackend::register_extension`.
//!
//! Run: `cargo run --release --example quickstart`

use anyhow::Result;
use backpack_rs::coordinator::train::{build_inputs, init_params};
use backpack_rs::data::{DatasetSpec, Synthetic};
use backpack_rs::runtime::Tensor;
use backpack_rs::{ArtifactId, Backend, Exec, NativeBackend, Signature};

fn main() -> Result<()> {
    let be = NativeBackend::new();
    // logreg (Linear(784, 10) + CrossEntropy) with every first-order
    // extension in one graph, addressed through the typed artifact
    // API (the string form `be.load("logreg_batch_grad+..._n64")`
    // still works and round-trips with `ArtifactId`).
    let sig = Signature::extract([
        "batch_grad",
        "batch_l2",
        "sq_moment",
        "variance",
    ])?;
    let id = ArtifactId::new("logreg", sig, 64)?;
    let exe = be.load_id(&id)?;
    let spec = exe.spec();
    println!(
        "artifact: {} ({} inputs, {} outputs)",
        spec.name,
        spec.inputs.len(),
        spec.outputs.len()
    );

    // Synthetic MNIST batch (DESIGN.md §3) + fan-in initialized params.
    let ds = Synthetic::new(DatasetSpec::by_name("mnist").unwrap(), 0);
    let idx: Vec<usize> = (0..64).collect();
    let (xv, yv) = ds.batch(0, &idx);
    let x = Tensor::from_f32(&[64, 784], xv);
    let y = Tensor::from_i32(&[64], yv);
    let params = init_params(spec, 0);

    // ONE extended backward pass.
    let out = exe.run(&build_inputs(&params, x, y, None))?;

    println!("\nloss = {:.4}\n", out.loss()?);
    println!("quantities extracted alongside the gradient:");
    for name in out.names() {
        let t = out.get(name)?;
        println!("  {name:24} shape {:?}", t.shape);
    }

    // param.grad / param.var for the weight, like Fig. 1's print.
    let grad = out.get("grad/0/w")?.f32s()?;
    let var = out.get("variance/0/w")?.f32s()?;
    let l2 = out.get("batch_l2/0/w")?.f32s()?;
    println!("\nweight grad[0..4]     = {:?}", &grad[..4]);
    println!("weight variance[0..4] = {:?}", &var[..4]);
    println!("indiv-grad L2 norms (first 4 samples) = {:?}", &l2[..4]);

    // Sanity: variance must be non-negative.
    assert!(var.iter().all(|v| *v >= -1e-6));

    // The same extraction through the engine API, with an explicit
    // execution topology. `Topology::local(N)` shards the batch over
    // N in-process threads; swapping in `Topology::workers(N)` (or
    // `Topology::Workers { n, addrs }` for pre-started workers) fans
    // the same call out to `backpack worker` processes over
    // backpack-shard/v1, merged by the same ReducePlan contract —
    // docs/distributed.md.
    let m = backpack_rs::Model::logreg();
    let tensors: Vec<Tensor> =
        params.iter().map(|p| p.tensor.clone()).collect();
    let (xv, yv) = ds.batch(0, &idx);
    let opts = backpack_rs::ExtractOptions {
        topology: backpack_rs::Topology::local(2),
        ..backpack_rs::ExtractOptions::default()
    };
    let eng = m.extended_backward(
        &tensors,
        &Tensor::from_f32(&[64, 784], xv),
        &Tensor::from_i32(&[64], yv),
        &["variance".to_string()],
        &opts,
    )?;
    println!(
        "\nengine API, Topology::local(2): loss = {:.4}, \
         {} quantities",
        eng["loss"].f32s()?[0],
        eng.len()
    );

    println!("\nquickstart OK");
    Ok(())
}
