//! End-to-end driver (deliverable (b) / DESIGN.md §5): train the 3c3d
//! network (895,210 parameters) on synthetic CIFAR-10 with a
//! second-order optimizer built on BackPACK quantities, for a few
//! hundred steps, logging the loss curve. Runs on the default
//! **native** backend -- no artifacts, no flags, no external
//! dependencies: the im2col conv subsystem executes the whole graph
//! and the KFAC-preconditioned update consumes its Kronecker factors.
//!
//! Run: `cargo run --release --example train_cifar10 -- [steps] [opt]`

use anyhow::Result;
use backpack_rs::backend;
use backpack_rs::coordinator::metrics::write_csv;
use backpack_rs::coordinator::{problems, train, TrainConfig};
use backpack_rs::optim::Hyper;

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().collect();
    let steps: usize =
        args.get(1).map(|s| s.parse()).transpose()?.unwrap_or(300);
    let opt = args.get(2).cloned().unwrap_or_else(|| "kfac".to_string());

    let be = backend::open("native")?;
    let problem = problems::by_name("cifar10_3c3d")?;
    let cfg = TrainConfig {
        problem: problem.codename.into(),
        optimizer: opt.clone(),
        // Grid-search winner for KFAC on this problem (results/logs/
        // fig7a.log): α = λ = 1e-2.
        hyper: Hyper { lr: 0.01, damping: 0.01, l2: 0.0 },
        steps,
        seed: 0,
        eval_every: 25,
        inv_every: 1,
        log_every: 5,
        verbose: true,
    };
    println!(
        "training 3c3d (895,210 params) on synthetic CIFAR-10 with \
         {opt} for {steps} steps..."
    );
    let log = train::train(be.as_ref(), problem, &cfg)?;

    println!("\nloss curve:");
    for (s, l) in &log.train_loss {
        println!("  step {s:4}  loss {l:.4}");
    }
    for e in &log.evals {
        println!(
            "  eval @ {:4}: test loss {:.4}, test acc {:.3}",
            e.step, e.test_loss, e.test_accuracy
        );
    }
    println!(
        "\n{:.1}s total, {:.1}ms/step artifact execution",
        log.wall_time_s,
        log.step_time_s * 1e3
    );

    let rows: Vec<Vec<String>> = log
        .train_loss
        .iter()
        .map(|(s, l)| vec![s.to_string(), l.to_string()])
        .collect();
    write_csv(
        std::path::Path::new("results/e2e_train_cifar10.csv"),
        "step,train_loss",
        &rows,
    )?;
    println!("wrote results/e2e_train_cifar10.csv");

    let first = log.train_loss.first().map(|x| x.1).unwrap_or(f32::NAN);
    let last = log.final_train_loss();
    anyhow::ensure!(
        !log.diverged && last < first,
        "training must reduce the loss (got {first} -> {last})"
    );
    println!("e2e training OK: loss {first:.3} -> {last:.3}");
    Ok(())
}
