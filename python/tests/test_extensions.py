"""Extension engine vs autodiff oracles.

The engine never uses jax.grad; these tests do, establishing that the
generalized backward pass reproduces:

* averaged gradient        == jax.grad of the mean loss
* individual gradients     == jax.vmap(jax.grad) (Goodfellow 2015 oracle)
* variance / 2nd moment / L2 == moments of the individual gradients
* DiagGGN                  == explicit J^T H J diagonal via jax.vjp
* Hessian diagonal         == jax.hessian of the loss (tanh/sigmoid MLPs)
* KFLR on a single linear layer (N=1) == exact GGN block (A ⊗ B exact)
* KFAC (MC)                ->  KFLR factors in expectation
* KFRA on logreg           == averaged loss Hessian (Eq. 24b)
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import models
from compile.extensions import extended_backward
from compile.losses import CrossEntropyLoss


def _data(model, n, seed=0):
    kx, ky = jax.random.split(jax.random.PRNGKey(seed))
    x = jax.random.normal(kx, (n,) + model.in_shape, jnp.float32)
    y = jax.random.randint(ky, (n,), 0, model.num_classes)
    return x, y


def _tiny_conv_net():
    from compile.layers import Conv2d, Flatten, Linear, MaxPool2d, ReLU
    from compile.models import SequentialModel
    return SequentialModel(
        "tiny_conv",
        [Conv2d(2, 3, 3, padding="SAME"), ReLU(),
         MaxPool2d(2, 2, "VALID"),
         Flatten(), Linear(3 * 3 * 3, 4)],
        CrossEntropyLoss(), (2, 6, 6), 4)


MODELS = {
    "mlp_tanh": lambda: models.mlp_tanh(),
    "tiny_conv": _tiny_conv_net,
    "logreg": lambda: models.logreg(in_dim=12, classes=4),
}


def _loss_fn(model):
    def f(params, x, y):
        return model.loss.value(model.forward(params, x), y)
    return f


@pytest.mark.parametrize("name", sorted(MODELS))
def test_grad_matches_jax_grad(name):
    model = MODELS[name]()
    params = model.init(jax.random.PRNGKey(1))
    x, y = _data(model, 6)
    out = extended_backward(model, params, x, y)
    want = jax.grad(_loss_fn(model))(params, x, y)
    for i in model.param_layer_indices():
        for k in ("w", "b"):
            np.testing.assert_allclose(
                out[f"grad/{i}/{k}"], want[i][k], rtol=1e-4, atol=1e-5,
                err_msg=f"{name} grad/{i}/{k}")


@pytest.mark.parametrize("name", sorted(MODELS))
def test_batch_grad_matches_vmap_grad(name):
    model = MODELS[name]()
    params = model.init(jax.random.PRNGKey(2))
    n = 5
    x, y = _data(model, n)
    out = extended_backward(model, params, x, y, ["batch_grad"])

    def single(params, xn, yn):
        return model.loss.value(model.forward(params, xn[None]),
                                yn[None])

    want = jax.vmap(jax.grad(single), in_axes=(None, 0, 0))(params, x, y)
    for i in model.param_layer_indices():
        for k in ("w", "b"):
            np.testing.assert_allclose(
                out[f"batch_grad/{i}/{k}"], want[i][k] / n,
                rtol=1e-4, atol=1e-5, err_msg=f"{name} {i}/{k}")


def test_first_order_moments_consistent():
    """variance/2nd-moment/L2 are exactly the moments of batch_grad."""
    model = MODELS["tiny_conv"]()
    params = model.init(jax.random.PRNGKey(3))
    n = 7
    x, y = _data(model, n)
    out = extended_backward(
        model, params, x, y,
        ["batch_grad", "batch_l2", "sq_moment", "variance"])
    for i in model.param_layer_indices():
        for k in ("w", "b"):
            ig = out[f"batch_grad/{i}/{k}"]          # (1/N) ∇ℓ_n
            grad = out[f"grad/{i}/{k}"]
            np.testing.assert_allclose(
                out[f"batch_l2/{i}/{k}"],
                jnp.sum(ig.reshape(n, -1) ** 2, axis=1),
                rtol=1e-4, atol=1e-6)
            sq = jnp.sum((ig * n) ** 2, axis=0) / n   # Table 1
            np.testing.assert_allclose(out[f"sq_moment/{i}/{k}"], sq,
                                       rtol=1e-4, atol=1e-6)
            np.testing.assert_allclose(out[f"variance/{i}/{k}"],
                                       sq - grad**2, rtol=1e-4, atol=1e-5)


def _diag_ggn_oracle(model, params, x, y):
    """Explicit GGN diagonal: 1/N Σ_n Σ_c [J^T S(:,c)]² via jax.vjp."""
    logits = model.forward(params, x)
    s = model.loss.sqrt_hessian(logits, y)  # [N, C, C]
    n, c = s.shape[0], s.shape[2]
    total = jax.tree.map(jnp.zeros_like, params)
    for i in range(n):
        _, vjp = jax.vjp(
            lambda p: model.forward(p, x[i:i + 1])[0], params)
        for j in range(c):
            g = vjp(s[i, :, j])[0]
            total = jax.tree.map(lambda t, v: t + v**2, total, g)
    return jax.tree.map(lambda t: t / n, total)


@pytest.mark.parametrize("name", sorted(MODELS))
def test_diag_ggn_matches_explicit(name):
    model = MODELS[name]()
    params = model.init(jax.random.PRNGKey(4))
    x, y = _data(model, 4)
    out = extended_backward(model, params, x, y, ["diag_ggn"])
    want = _diag_ggn_oracle(model, params, x, y)
    for i in model.param_layer_indices():
        for k in ("w", "b"):
            np.testing.assert_allclose(
                out[f"diag_ggn/{i}/{k}"], want[i][k],
                rtol=1e-3, atol=1e-5, err_msg=f"{name} {i}/{k}")


def test_sqrt_hessian_factorizes_loss_hessian():
    """S Sᵀ == ∇²_f ℓ_n from jax.hessian, per sample."""
    loss = CrossEntropyLoss()
    logits = jax.random.normal(jax.random.PRNGKey(5), (3, 6))
    y = jnp.array([0, 3, 5])
    s = loss.sqrt_hessian(logits, y)
    for i in range(3):
        want = jax.hessian(
            lambda f: loss.value(f[None], y[i:i + 1]))(logits[i])
        np.testing.assert_allclose(s[i] @ s[i].T, want,
                                   rtol=1e-4, atol=1e-5)


def test_mc_sqrt_hessian_unbiased():
    loss = CrossEntropyLoss()
    logits = jax.random.normal(jax.random.PRNGKey(6), (2, 4))
    y = jnp.array([1, 2])
    s = loss.sqrt_hessian_mc(logits, y, jax.random.PRNGKey(7),
                             samples=4000)
    approx = jnp.einsum("ncm,ndm->ncd", s, s)
    exact = loss.sqrt_hessian(logits, y)
    exact = jnp.einsum("ncm,ndm->ncd", exact, exact)
    np.testing.assert_allclose(approx, exact, atol=0.03)


@pytest.mark.parametrize("name", ["mlp_tanh", "mlp_sigmoid"])
def test_diag_h_matches_jax_hessian(name):
    """Exact Hessian diagonal with non-piecewise-linear activations
    (the Appendix A.3 residual machinery) vs brute-force jax.hessian."""
    model = (models.mlp_tanh(in_dim=6, hidden=(5, 4), classes=3)
             if name == "mlp_tanh"
             else models.mlp_sigmoid(in_dim=6, hidden=(5,), classes=3))
    params = model.init(jax.random.PRNGKey(8))
    x, y = _data(model, 3)
    out = extended_backward(model, params, x, y, ["diag_h"])
    hess = jax.hessian(_loss_fn(model))(params, x, y)
    for i in model.param_layer_indices():
        for k in ("w", "b"):
            block = hess[i][k][i][k]
            d = int(np.prod(params[i][k].shape))
            want = jnp.diag(block.reshape(d, d)).reshape(
                params[i][k].shape)
            np.testing.assert_allclose(
                out[f"diag_h/{i}/{k}"], want, rtol=1e-3, atol=1e-4,
                err_msg=f"{name} {i}/{k}")


def test_diag_h_equals_diag_ggn_for_relu_net():
    """Piecewise-linear nets: Hessian diag == GGN diag (Appendix B)."""
    model = MODELS["tiny_conv"]()
    params = model.init(jax.random.PRNGKey(9))
    x, y = _data(model, 4)
    out = extended_backward(model, params, x, y, ["diag_ggn", "diag_h"])
    for i in model.param_layer_indices():
        for k in ("w", "b"):
            np.testing.assert_allclose(
                out[f"diag_h/{i}/{k}"], out[f"diag_ggn/{i}/{k}"],
                rtol=1e-4, atol=1e-6)


def test_kflr_exact_on_single_linear_layer_batch1():
    """N=1, one linear layer: G = A ⊗ B exactly."""
    model = models.logreg(in_dim=5, classes=3)
    params = model.init(jax.random.PRNGKey(10))
    x, y = _data(model, 1)
    out = extended_backward(model, params, x, y, ["kflr", "diag_ggn"])
    a, b = out["kflr/0/A"], out["kflr/0/B"]
    # check the diagonal of A ⊗ B against DiagGGN (w block: [out, in])
    want = out["diag_ggn/0/w"]
    got = jnp.outer(jnp.diag(b), jnp.diag(a))
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-6)
    # bias block is the full GGN of the bias
    np.testing.assert_allclose(jnp.diag(out["kflr/0/bias_ggn"]),
                               out["diag_ggn/0/b"], rtol=1e-4, atol=1e-6)


def test_kfac_converges_to_kflr_in_expectation():
    model = models.logreg(in_dim=6, classes=4)
    params = model.init(jax.random.PRNGKey(11))
    x, y = _data(model, 4)
    out = extended_backward(model, params, x, y, ["kfac", "kflr"],
                            key=jax.random.PRNGKey(12), mc_samples=3000)
    np.testing.assert_allclose(out["kfac/0/A"], out["kflr/0/A"],
                               rtol=1e-5, atol=1e-6)  # A is MC-free
    np.testing.assert_allclose(out["kfac/0/B"], out["kflr/0/B"],
                               atol=0.02)


def test_kfra_on_logreg_is_mean_loss_hessian():
    model = models.logreg(in_dim=7, classes=5)
    params = model.init(jax.random.PRNGKey(13))
    x, y = _data(model, 6)
    out = extended_backward(model, params, x, y, ["kfra"])
    logits = model.forward(params, x)
    want = model.loss.hessian_mean(logits, y)
    np.testing.assert_allclose(out["kfra/0/B"], want, rtol=1e-5,
                               atol=1e-6)


def test_kfra_propagation_through_linear_mlp():
    """For a deep LINEAR network the averaged propagation is exact:
    B_KFRA at layer 0 == mean_n W₁ᵀ H_n W₁."""
    from compile.layers import Linear
    from compile.models import SequentialModel
    model = SequentialModel(
        "deep_linear", [Linear(5, 4), Linear(4, 3)],
        CrossEntropyLoss(), (5,), 3)
    params = model.init(jax.random.PRNGKey(14))
    x, y = _data(model, 5)
    out = extended_backward(model, params, x, y, ["kfra"])
    logits = model.forward(params, x)
    h = model.loss.hessian_mean(logits, y)
    w1 = params[1]["w"]
    np.testing.assert_allclose(out["kfra/0/B"], w1.T @ h @ w1,
                               rtol=1e-4, atol=1e-6)


def test_conv_kron_factors_shapes_and_psd():
    model = MODELS["tiny_conv"]()
    params = model.init(jax.random.PRNGKey(15))
    x, y = _data(model, 4)
    out = extended_backward(model, params, x, y, ["kfac"],
                            key=jax.random.PRNGKey(16))
    a, b = out["kfac/0/A"], out["kfac/0/B"]
    assert a.shape == (2 * 9, 2 * 9) and b.shape == (3, 3)
    for m in (a, b):
        eig = np.linalg.eigvalsh(np.asarray(m))
        assert eig.min() > -1e-5, "Kronecker factor must be PSD"


def test_diag_ggn_mc_close_with_many_samples():
    model = models.logreg(in_dim=6, classes=3)
    params = model.init(jax.random.PRNGKey(17))
    x, y = _data(model, 4)
    out = extended_backward(
        model, params, x, y, ["diag_ggn", "diag_ggn_mc"],
        key=jax.random.PRNGKey(18), mc_samples=4000)
    np.testing.assert_allclose(out["diag_ggn_mc/0/w"],
                               out["diag_ggn/0/w"], atol=0.02)


def test_mc_extension_without_key_raises():
    model = models.logreg(in_dim=4, classes=3)
    params = model.init(jax.random.PRNGKey(19))
    x, y = _data(model, 2)
    with pytest.raises(ValueError, match="PRNG key"):
        extended_backward(model, params, x, y, ["kfac"])


def test_unknown_extension_raises():
    model = models.logreg(in_dim=4, classes=3)
    params = model.init(jax.random.PRNGKey(20))
    x, y = _data(model, 2)
    with pytest.raises(ValueError, match="unknown"):
        extended_backward(model, params, x, y, ["bogus"])
