"""Loss-function derivative interfaces vs autodiff."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.losses import CrossEntropyLoss, MSELoss


def test_ce_value_and_grad_match_jax():
    loss = CrossEntropyLoss()
    logits = jax.random.normal(jax.random.PRNGKey(0), (5, 7))
    y = jnp.array([0, 1, 2, 3, 6])
    got = loss.grad(logits, y)
    # grad of the PER-SAMPLE loss (no 1/N)
    for i in range(5):
        want = jax.grad(
            lambda f: loss.value(f[None], y[i:i + 1]))(logits[i])
        np.testing.assert_allclose(got[i], want, rtol=1e-5, atol=1e-6)


def test_ce_per_sample_mean_is_value():
    loss = CrossEntropyLoss()
    logits = jax.random.normal(jax.random.PRNGKey(1), (6, 4))
    y = jnp.array([0, 1, 2, 3, 0, 1])
    np.testing.assert_allclose(
        jnp.mean(loss.per_sample(logits, y)), loss.value(logits, y),
        rtol=1e-6)


def test_ce_hessian_mean_matches_average_of_hessians():
    loss = CrossEntropyLoss()
    logits = jax.random.normal(jax.random.PRNGKey(2), (4, 5))
    y = jnp.array([0, 1, 2, 3])
    want = jnp.mean(
        jnp.stack([
            jax.hessian(lambda f: loss.value(f[None], y[i:i + 1]))(
                logits[i])
            for i in range(4)
        ]),
        axis=0,
    )
    np.testing.assert_allclose(loss.hessian_mean(logits, y), want,
                               rtol=1e-4, atol=1e-6)


def test_ce_accuracy():
    loss = CrossEntropyLoss()
    logits = jnp.array([[2.0, 0.0], [0.0, 2.0], [2.0, 0.0]])
    y = jnp.array([0, 1, 1])
    assert float(loss.accuracy(logits, y)) == pytest.approx(2 / 3)


def test_mse_sqrt_hessian_factorizes():
    loss = MSELoss()
    logits = jax.random.normal(jax.random.PRNGKey(3), (3, 4))
    y = jax.random.normal(jax.random.PRNGKey(4), (3, 4))
    s = loss.sqrt_hessian(logits, y)
    for i in range(3):
        want = jax.hessian(
            lambda f: loss.value(f[None], y[i:i + 1]))(logits[i])
        np.testing.assert_allclose(s[i] @ s[i].T, want, rtol=1e-5,
                                   atol=1e-6)


def test_mse_grad_matches_jax():
    loss = MSELoss()
    logits = jax.random.normal(jax.random.PRNGKey(5), (4, 3))
    y = jax.random.normal(jax.random.PRNGKey(6), (4, 3))
    got = loss.grad(logits, y)
    for i in range(4):
        want = jax.grad(
            lambda f: loss.value(f[None], y[i:i + 1]))(logits[i])
        np.testing.assert_allclose(got[i], want, rtol=1e-5, atol=1e-6)


def test_mse_mc_sqrt_hessian_unbiased():
    loss = MSELoss()
    logits = jnp.zeros((2, 3))
    y = jnp.zeros((2, 3))
    s = loss.sqrt_hessian_mc(logits, y, jax.random.PRNGKey(7),
                             samples=4000)
    approx = jnp.einsum("ncm,ndm->ncd", s, s)
    want = 2.0 * jnp.broadcast_to(jnp.eye(3), (2, 3, 3))
    np.testing.assert_allclose(approx, want, atol=0.15)


def test_ce_mc_multi_sample_reduces_variance():
    loss = CrossEntropyLoss()
    logits = jax.random.normal(jax.random.PRNGKey(8), (4, 6))
    y = jnp.array([0, 1, 2, 3])
    exact = loss.sqrt_hessian(logits, y)
    exact = jnp.einsum("ncm,ndm->ncd", exact, exact)

    def mc_err(samples, key):
        s = loss.sqrt_hessian_mc(logits, y, key, samples=samples)
        approx = jnp.einsum("ncm,ndm->ncd", s, s)
        return float(jnp.mean((approx - exact) ** 2))

    keys = [jax.random.PRNGKey(k) for k in range(10, 20)]
    err1 = np.mean([mc_err(1, k) for k in keys])
    err32 = np.mean([mc_err(32, k) for k in keys])
    assert err32 < err1 / 4, (err1, err32)
