"""L1 correctness: Pallas kernels vs pure-jnp oracles.

Hypothesis sweeps shapes (including non-tile-aligned ones that exercise
the padding paths) and both block-plan targets; assert_allclose against
ref.py is THE correctness signal for the kernels that end up inside the
AOT artifacts.
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from compile.kernels import pallas_impl as pk
from compile.kernels import ref, ops

DIMS = st.integers(min_value=1, max_value=37)
BATCH = st.integers(min_value=1, max_value=19)
TARGETS = st.sampled_from(["cpu", "tpu"])


def _arr(rng, *shape):
    return jnp.asarray(rng.standard_normal(shape), dtype=jnp.float32)


@settings(max_examples=25, deadline=None)
@given(n=BATCH, b=DIMS, a=DIMS, target=TARGETS, seed=st.integers(0, 2**31))
def test_matmul_tn_matches_ref(n, b, a, target, seed):
    rng = np.random.default_rng(seed)
    p, q = _arr(rng, n, b), _arr(rng, n, a)
    got = pk.matmul_tn_pallas(p, q, target=target)
    want = ref.matmul_tn_ref(p, q)
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=1e-5)


@settings(max_examples=25, deadline=None)
@given(n=BATCH, b=DIMS, a=DIMS, target=TARGETS, seed=st.integers(0, 2**31))
def test_outer_batch_matches_ref(n, b, a, target, seed):
    rng = np.random.default_rng(seed)
    g, x = _arr(rng, n, b), _arr(rng, n, a)
    got = pk.outer_batch_pallas(g, x, target=target)
    want = ref.outer_batch_ref(g, x)
    np.testing.assert_allclose(got, want, rtol=1e-6, atol=1e-6)


@settings(max_examples=25, deadline=None)
@given(n=BATCH, b=DIMS, a=DIMS, target=TARGETS, seed=st.integers(0, 2**31))
def test_batch_l2_matches_ref(n, b, a, target, seed):
    rng = np.random.default_rng(seed)
    g, x = _arr(rng, n, b), _arr(rng, n, a)
    got = pk.batch_l2_pallas(g, x, target=target)
    want = ref.batch_l2_ref(g, x)
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=1e-5)


@settings(max_examples=25, deadline=None)
@given(n=BATCH, b=DIMS, c=st.integers(1, 11), target=TARGETS,
       seed=st.integers(0, 2**31))
def test_sq_reduce_matches_ref(n, b, c, target, seed):
    rng = np.random.default_rng(seed)
    s = _arr(rng, n, b, c)
    got = pk.sq_reduce_pallas(s, target=target)
    want = ref.sq_reduce_ref(s)
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=1e-5)


# -- composition-level identities against brute force ------------------------


def test_sq_moment_is_sum_of_squared_per_sample_grads():
    rng = np.random.default_rng(0)
    g, x = _arr(rng, 7, 5), _arr(rng, 7, 11)
    indiv = ref.outer_batch_ref(g, x)  # [N, B, A]
    want = jnp.sum(indiv**2, axis=0)
    got = ops.sq_moment(g, x)
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=1e-5)


def test_batch_l2_is_frobenius_norm_of_per_sample_grads():
    rng = np.random.default_rng(1)
    g, x = _arr(rng, 6, 4), _arr(rng, 6, 9)
    indiv = ref.outer_batch_ref(g, x)
    want = jnp.sum(indiv**2, axis=(1, 2))
    got = ops.batch_l2(g, x)
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=1e-5)


def test_diag_ggn_from_sqrt_matches_explicit_ggn():
    rng = np.random.default_rng(2)
    n, a, b, c = 5, 6, 4, 3
    x = _arr(rng, n, a)
    s = _arr(rng, n, b, c)
    # Explicit: per-sample Jacobian of W -> z is x_n (kron), GGN block diag.
    # diag[b,a] = sum_n sum_c (x[n,a] * s[n,b,c])^2
    want = jnp.einsum("na,nbc->ba", x**2, s**2)
    got = ops.diag_ggn_from_sqrt(s, x)
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=1e-5)


def test_kron_factors_match_definitions():
    rng = np.random.default_rng(3)
    n, b, c = 8, 5, 4
    x = _arr(rng, n, 7)
    s = _arr(rng, n, b, c)
    np.testing.assert_allclose(
        ops.kron_factor_A(x), jnp.einsum("na,nb->ab", x, x) / n,
        rtol=2e-5, atol=1e-5)
    np.testing.assert_allclose(
        ops.kron_factor_B(s), jnp.einsum("nbc,ndc->bd", s, s) / n,
        rtol=2e-5, atol=1e-5)


def test_zero_inputs_give_zero():
    z2 = jnp.zeros((3, 4), jnp.float32)
    z3 = jnp.zeros((3, 4, 2), jnp.float32)
    assert float(jnp.abs(pk.matmul_tn_pallas(z2, z2)).max()) == 0.0
    assert float(jnp.abs(pk.outer_batch_pallas(z2, z2)).max()) == 0.0
    assert float(jnp.abs(pk.batch_l2_pallas(z2, z2)).max()) == 0.0
    assert float(jnp.abs(pk.sq_reduce_pallas(z3)).max()) == 0.0


@pytest.mark.parametrize("target", ["cpu", "tpu"])
def test_large_nonaligned_shapes(target):
    """Shapes straddling several tiles with remainders on every axis."""
    rng = np.random.default_rng(4)
    g, x = _arr(rng, 130, 257), _arr(rng, 130, 131)
    np.testing.assert_allclose(
        pk.matmul_tn_pallas(g, x, target=target), ref.matmul_tn_ref(g, x),
        rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(
        pk.batch_l2_pallas(g, x, target=target), ref.batch_l2_ref(g, x),
        rtol=1e-4, atol=1e-3)
