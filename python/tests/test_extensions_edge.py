"""Edge cases and failure-injection for the extension engine."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import models
from compile.extensions import extended_backward
from compile.layers import Conv2d, Flatten, GlobalAvgPool2d, Linear, ReLU
from compile.losses import CrossEntropyLoss
from compile.models import SequentialModel


def _data(model, n, seed=0):
    kx, ky = jax.random.split(jax.random.PRNGKey(seed))
    x = jax.random.normal(kx, (n,) + model.in_shape, jnp.float32)
    y = jax.random.randint(ky, (n,), 0, model.num_classes)
    return x, y


def test_no_extensions_yields_only_loss_and_grads():
    model = models.logreg(in_dim=6, classes=3)
    params = model.init(jax.random.PRNGKey(0))
    x, y = _data(model, 4)
    out = extended_backward(model, params, x, y)
    assert sorted(out) == ["grad/0/b", "grad/0/w", "loss"]


def test_batch_size_one():
    """N=1: variance must be exactly zero (single sample = its mean)."""
    model = models.logreg(in_dim=5, classes=3)
    params = model.init(jax.random.PRNGKey(1))
    x, y = _data(model, 1)
    out = extended_backward(model, params, x, y, ["variance"])
    np.testing.assert_allclose(out["variance/0/w"], 0.0, atol=1e-7)


def test_strided_conv_net_extensions():
    """Stride-2 convs (the All-CNN-C pattern) through the whole stack."""
    model = SequentialModel(
        "strided",
        [Conv2d(2, 4, 3, stride=2, padding="SAME"), ReLU(),
         Conv2d(4, 3, 1, padding="VALID"), ReLU(),
         GlobalAvgPool2d()],
        CrossEntropyLoss(), (2, 8, 8), 3)
    params = model.init(jax.random.PRNGKey(2))
    x, y = _data(model, 3)
    out = extended_backward(
        model, params, x, y, ["batch_grad", "diag_ggn"])

    def single(params, xn, yn):
        return model.loss.value(model.forward(params, xn[None]),
                                yn[None])

    want = jax.vmap(jax.grad(single), in_axes=(None, 0, 0))(params, x, y)
    for i in model.param_layer_indices():
        np.testing.assert_allclose(
            out[f"batch_grad/{i}/w"], want[i]["w"] / 3,
            rtol=1e-4, atol=1e-5)
    # GGN diag of a ReLU net is also the Hessian diag: must be >= 0.
    for i in model.param_layer_indices():
        assert float(out[f"diag_ggn/{i}/w"].min()) >= -1e-7


def test_global_avg_pool_ggn_vs_oracle():
    model = SequentialModel(
        "gap", [Conv2d(1, 3, 3, padding="SAME"), GlobalAvgPool2d()],
        CrossEntropyLoss(), (1, 5, 5), 3)
    params = model.init(jax.random.PRNGKey(3))
    x, y = _data(model, 2)
    out = extended_backward(model, params, x, y, ["diag_ggn"])
    logits = model.forward(params, x)
    s = model.loss.sqrt_hessian(logits, y)
    total = jax.tree.map(jnp.zeros_like, params)
    for i in range(2):
        _, vjp = jax.vjp(lambda p: model.forward(p, x[i:i + 1])[0],
                         params)
        for c in range(3):
            g = vjp(s[i, :, c])[0]
            total = jax.tree.map(lambda t, v: t + v**2, total, g)
    np.testing.assert_allclose(
        out["diag_ggn/0/w"], total[0]["w"] / 2, rtol=1e-3, atol=1e-6)


def test_kfra_raises_on_conv_models():
    """Paper footnote 5: KFRA's averaged backward does not extend to
    large convolutions; the engine refuses rather than silently
    approximating."""
    model = SequentialModel(
        "conv", [Conv2d(1, 2, 3, padding="SAME"), Flatten(),
                 Linear(2 * 4 * 4, 3)],
        CrossEntropyLoss(), (1, 4, 4), 3)
    params = model.init(jax.random.PRNGKey(4))
    x, y = _data(model, 2)
    with pytest.raises(NotImplementedError, match="footnote 5"):
        extended_backward(model, params, x, y, ["kfra"])


def test_multiple_extensions_in_one_pass_are_consistent():
    """Requesting everything at once must match separate passes."""
    model = models.mlp_tanh(in_dim=8, hidden=(6,), classes=4)
    params = model.init(jax.random.PRNGKey(5))
    x, y = _data(model, 5)
    key = jax.random.PRNGKey(6)
    combined = extended_backward(
        model, params, x, y,
        ["batch_grad", "variance", "diag_ggn", "diag_h", "kflr"],
        key=key)
    for ext in ["batch_grad", "variance", "diag_ggn", "diag_h", "kflr"]:
        alone = extended_backward(model, params, x, y, [ext], key=key)
        for k, v in alone.items():
            np.testing.assert_allclose(
                combined[k], v, rtol=1e-5, atol=1e-6, err_msg=k)


def test_mc_samples_parameter_shapes():
    model = models.logreg(in_dim=5, classes=3)
    params = model.init(jax.random.PRNGKey(7))
    x, y = _data(model, 4)
    out = extended_backward(model, params, x, y, ["diag_ggn_mc"],
                            key=jax.random.PRNGKey(8), mc_samples=7)
    assert out["diag_ggn_mc/0/w"].shape == (3, 5)


def test_extension_outputs_all_finite():
    model = models.two_c2d(side=12, classes=4)  # small variant
    params = model.init(jax.random.PRNGKey(9))
    x, y = _data(model, 2)
    out = extended_backward(
        model, params, x, y,
        ["batch_l2", "sq_moment", "variance", "diag_ggn"])
    for k, v in out.items():
        assert bool(jnp.all(jnp.isfinite(v))), k
