"""AOT pipeline checks: the spec table, manifest integrity, and
jit-vs-eager numerical equivalence of a lowered graph."""

import json
import pathlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, models
from compile.extensions import extended_backward
from compile.hlo_util import lower_to_hlo_text

ART = pathlib.Path(__file__).resolve().parents[2] / "artifacts"


def test_spec_table_names_unique_and_cover_figures():
    specs = aot.spec_table()
    names = [s[0] for s in specs]
    assert len(names) == len(set(names)), "duplicate artifact names"
    # every figure's artifacts exist in the table (DESIGN.md §5)
    for required in [
        "3c3d_grad_n1",              # Fig. 3 for-loop baseline
        "3c3d_batch_grad_n32",       # Fig. 3
        "3c3d_kflr_n64",             # Fig. 6
        "allcnnc32_kflr_n8",         # Fig. 8
        "3c3d_sigmoid_diag_h_n8",    # Fig. 9
        "logreg_kfra_n64",           # Fig. 10 / Table 4
        "allcnnc16_kfac_n16",        # Fig. 7b
    ]:
        assert required in names, required


def test_manifest_matches_artifacts_on_disk():
    if not (ART / "manifest.json").exists():
        pytest.skip("artifacts not built")
    manifest = json.loads((ART / "manifest.json").read_text())
    assert manifest["source_hash"] == aot.source_hash(), (
        "stale artifacts: run `make artifacts`")
    for name, spec in manifest["artifacts"].items():
        assert (ART / spec["file"]).exists(), name
        # inputs: params..., x, y, [key]
        names = [t["name"] for t in spec["inputs"]]
        assert names[-2 - int(spec["has_key"])] == "x"
        assert "loss" in [t["name"] for t in spec["outputs"]]


def test_lowered_graph_matches_eager():
    """HLO-text lowering preserves numerics: run the same extended
    backward eagerly and through jax.jit-of-the-artifact-function."""
    model = models.logreg(in_dim=20, classes=5)
    params = model.init(jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (8, 20))
    y = jax.random.randint(jax.random.PRNGKey(2), (8,), 0, 5)

    def fn(w, b, x, y):
        out = extended_backward(
            model, [{"w": w, "b": b}], x, y,
            ["batch_l2", "variance", "diag_ggn"])
        names = sorted(out)
        return tuple(out[k] for k in names)

    eager = fn(params[0]["w"], params[0]["b"], x, y)
    jitted = jax.jit(fn)(params[0]["w"], params[0]["b"], x, y)
    for e, j in zip(eager, jitted):
        np.testing.assert_allclose(e, j, rtol=1e-5, atol=1e-6)
    # and the graph lowers to parseable HLO text
    text = lower_to_hlo_text(
        fn,
        (jax.ShapeDtypeStruct((5, 20), jnp.float32),
         jax.ShapeDtypeStruct((5,), jnp.float32),
         jax.ShapeDtypeStruct((8, 20), jnp.float32),
         jax.ShapeDtypeStruct((8,), jnp.int32)))
    assert text.startswith("HloModule"), text[:40]
    assert "ROOT" in text


def test_source_hash_changes_with_spec():
    h = aot.source_hash()
    assert isinstance(h, str) and len(h) == 64
    assert h == aot.source_hash(), "hash must be deterministic"
