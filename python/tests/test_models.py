"""Model zoo checks: Table 3 parameter counts are the paper's checksums."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import models


@pytest.mark.parametrize("name,count", sorted(
    models.PAPER_PARAM_COUNTS.items()))
def test_param_counts_match_paper_table3(name, count):
    model = models.MODELS[name]()
    params = model.init(jax.random.PRNGKey(0))
    assert model.num_params(params) == count


def test_allcnnc_param_count_invariant_to_spatial_size():
    """Fully convolutional => the 16x16 CPU-scaled training variant keeps
    the paper's parameter count (DESIGN.md §3)."""
    for side in (16, 32):
        model = models.allcnnc(side=side)
        params = model.init(jax.random.PRNGKey(0))
        assert model.num_params(params) == 1_387_108


def test_sigmoid_variant_same_count_as_3c3d():
    m = models.three_c3d_sigmoid()
    p = m.init(jax.random.PRNGKey(0))
    assert m.num_params(p) == 895_210


@pytest.mark.parametrize("name", ["logreg", "2c2d", "3c3d"])
def test_forward_shapes(name):
    model = models.MODELS[name]()
    params = model.init(jax.random.PRNGKey(1))
    n = 4
    x = jnp.zeros((n,) + model.in_shape, jnp.float32)
    logits = model.forward(params, x)
    assert logits.shape == (n, model.num_classes)


def test_allcnnc_forward_16():
    model = models.allcnnc(side=16)
    params = model.init(jax.random.PRNGKey(1))
    x = jnp.zeros((2, 3, 16, 16), jnp.float32)
    assert model.forward(params, x).shape == (2, 100)


def test_forward_finite_on_random_input():
    model = models.three_c3d()
    params = model.init(jax.random.PRNGKey(2))
    x = jax.random.normal(jax.random.PRNGKey(3), (2, 3, 32, 32))
    out = model.forward(params, x)
    assert bool(jnp.all(jnp.isfinite(out)))
