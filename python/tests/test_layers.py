"""Per-module Jacobian applications vs jax.vjp (the AD oracle).

Each module claims to know how to multiply with its (transposed)
Jacobians (Sec. 2.1); here jax's AD verifies every claim, per layer type,
including the matrix-shaped propagation used by second-order extensions.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import layers as L


def _vjp_oracle(fwd, x, g):
    _, vjp = jax.vjp(fwd, x)
    return vjp(g)[0]


def _check_vjp(layer, params, x, atol=1e-5):
    rng = np.random.default_rng(0)
    out = layer.forward(params, x)
    g = jnp.asarray(rng.standard_normal(out.shape), jnp.float32)
    got = layer.vjp_input(params, x, g)
    want = _vjp_oracle(lambda t: layer.forward(params, t), x, g)
    np.testing.assert_allclose(got, want, atol=atol, rtol=1e-4)
    # matrix propagation == columnwise vjp
    c = 3
    s = jnp.asarray(rng.standard_normal(out.shape + (c,)), jnp.float32)
    got_m = layer.mat_vjp_input(params, x, s)
    for j in range(c):
        np.testing.assert_allclose(
            got_m[..., j],
            _vjp_oracle(lambda t: layer.forward(params, t), x, s[..., j]),
            atol=atol, rtol=1e-4)


def _check_param_grad(layer, params, x):
    """batch_grad summed over N must equal jax.grad of sum-loss."""
    rng = np.random.default_rng(1)
    out = layer.forward(params, x)
    g = jnp.asarray(rng.standard_normal(out.shape), jnp.float32)

    def scalar(p):
        return jnp.sum(layer.forward(p, x) * g)

    want = jax.grad(scalar)(params)
    got = layer.batch_grad(params, x, g)
    for k in params:
        np.testing.assert_allclose(
            jnp.sum(got[k], axis=0), want[k], atol=1e-4, rtol=1e-4)
    # per-sample grads: sample n of batch_grad == grad on the 1-batch
    for n in (0, x.shape[0] - 1):
        def scalar_n(p):
            return jnp.sum(layer.forward(p, x[n:n + 1]) * g[n:n + 1])
        want_n = jax.grad(scalar_n)(params)
        for k in params:
            np.testing.assert_allclose(
                got[k][n], want_n[k], atol=1e-4, rtol=1e-4)


def _mk(key, *shape):
    return jax.random.normal(jax.random.PRNGKey(key), shape, jnp.float32)


def test_linear():
    layer = L.Linear(7, 5)
    params, out_shape = layer.init(jax.random.PRNGKey(0), (7,))
    assert out_shape == (5,)
    x = _mk(1, 4, 7)
    _check_vjp(layer, params, x)
    _check_param_grad(layer, params, x)


@pytest.mark.parametrize("stride,padding", [(1, "SAME"), (1, "VALID"),
                                            (2, "SAME")])
def test_conv2d(stride, padding):
    layer = L.Conv2d(3, 4, 3, stride=stride, padding=padding)
    params, out_shape = layer.init(jax.random.PRNGKey(0), (3, 8, 8))
    x = _mk(2, 2, 3, 8, 8)
    assert layer.forward(params, x).shape == (2, 4) + out_shape[1:]
    _check_vjp(layer, params, x)
    _check_param_grad(layer, params, x)


@pytest.mark.parametrize("act", [L.ReLU(), L.Sigmoid(), L.Tanh()])
def test_activations(act):
    params, _ = act.init(jax.random.PRNGKey(0), (6,))
    x = _mk(3, 5, 6)
    _check_vjp(act, params, x)


@pytest.mark.parametrize("act", [L.Sigmoid(), L.Tanh()])
def test_activation_second_derivative(act):
    """σ'' via finite differences of σ'."""
    x = jnp.linspace(-3, 3, 41)
    eps = 1e-3
    fd = (act.d_act(x + eps) - act.d_act(x - eps)) / (2 * eps)
    np.testing.assert_allclose(act.d2_act(x), fd, atol=1e-3)  # f32 FD noise


def test_maxpool():
    layer = L.MaxPool2d(3, 2, "SAME")
    params, out_shape = layer.init(jax.random.PRNGKey(0), (2, 9, 9))
    x = _mk(4, 3, 2, 9, 9)
    assert layer.forward(params, x).shape == (3,) + tuple(out_shape)
    _check_vjp(layer, params, x)


def test_flatten():
    layer = L.Flatten()
    params, out_shape = layer.init(jax.random.PRNGKey(0), (2, 3, 4))
    assert out_shape == (24,)
    x = _mk(5, 3, 2, 3, 4)
    _check_vjp(layer, params, x)


def test_global_avg_pool():
    layer = L.GlobalAvgPool2d()
    params, out_shape = layer.init(jax.random.PRNGKey(0), (5, 4, 4))
    assert out_shape == (5,)
    x = _mk(6, 3, 5, 4, 4)
    _check_vjp(layer, params, x)


def test_linear_batch_l2_and_sq_moment_vs_batch_grad():
    layer = L.Linear(6, 4)
    params, _ = layer.init(jax.random.PRNGKey(0), (6,))
    x, g = _mk(7, 5, 6), _mk(8, 5, 4)
    bg = layer.batch_grad(params, x, g)
    l2 = layer.batch_l2(params, x, g)
    sq = layer.sq_moment(params, x, g)
    for k in ("w", "b"):
        flat = bg[k].reshape(5, -1)
        np.testing.assert_allclose(l2[k], jnp.sum(flat**2, 1),
                                   rtol=1e-4, atol=1e-5)
        np.testing.assert_allclose(sq[k], jnp.sum(bg[k] ** 2, 0),
                                   rtol=1e-4, atol=1e-5)


def test_conv_batch_grad_bias_is_spatial_sum():
    layer = L.Conv2d(2, 3, 3)
    params, _ = layer.init(jax.random.PRNGKey(0), (2, 6, 6))
    x, g = _mk(9, 4, 2, 6, 6), _mk(10, 4, 3, 6, 6)
    bg = layer.batch_grad(params, x, g)
    np.testing.assert_allclose(bg["b"], jnp.sum(g, axis=(2, 3)),
                               rtol=1e-4, atol=1e-5)
