"""Model zoo: the DeepOBS test problems of Table 3, exactly.

Parameter counts are the paper's own checksums and are asserted by
``python/tests/test_models.py``:

========  ==================================  =============  ===========
codename  description                         dataset        # params
========  ==================================  =============  ===========
logreg    linear model                        MNIST          7,850
2c2d      2 conv + 2 dense                    Fashion-MNIST  3,274,634
3c3d      3 conv + 3 dense                    CIFAR-10       895,210
allcnnc   9 conv (Springenberg et al., 2015)  CIFAR-100      1,387,108
========  ==================================  =============  ===========

`3c3d_sigmoid` inserts a single sigmoid before the last classification
layer -- the Fig. 9 configuration ("we modify the smaller network used in
our benchmarks to include a single sigmoid activation function before the
last classification layer").

All-CNN-C is fully convolutional: its parameter count is invariant to the
input's spatial size, which lets the CPU-scaled training runs use 16×16
inputs (DESIGN.md §3) while keeping 1,387,108 parameters.
"""

from typing import List, Tuple

import jax
import jax.numpy as jnp

from .layers import (Conv2d, Flatten, GlobalAvgPool2d, Linear, MaxPool2d,
                     Module, ReLU, Sigmoid, Tanh)
from .losses import CrossEntropyLoss, MSELoss


class SequentialModel:
    """A sequence of modules + a loss (the paper's Eq. 2 setting)."""

    def __init__(self, name: str, layers: List[Module], loss,
                 in_shape: Tuple[int, ...], num_classes: int):
        self.name = name
        self.layers = layers
        self.loss = loss
        self.in_shape = tuple(in_shape)
        self.num_classes = num_classes

    def init(self, key):
        """Returns the list of per-layer param dicts (possibly empty)."""
        params = []
        shape = self.in_shape
        for layer in self.layers:
            key, sub = jax.random.split(key)
            p, shape = layer.init(sub, shape)
            params.append(p)
        assert shape == (self.num_classes,), (self.name, shape)
        return params

    def forward(self, params, x):
        for layer, p in zip(self.layers, params):
            x = layer.forward(p, x)
        return x

    def num_params(self, params) -> int:
        return sum(int(v.size) for p in params for v in p.values())

    def param_layer_indices(self):
        return [i for i, l in enumerate(self.layers) if l.has_params]


def logreg(in_dim: int = 784, classes: int = 10) -> SequentialModel:
    """Linear model on flattened MNIST (7,850 parameters)."""
    return SequentialModel(
        "logreg", [Linear(in_dim, classes)], CrossEntropyLoss(),
        (in_dim,), classes)


def two_c2d(side: int = 28, classes: int = 10) -> SequentialModel:
    """DeepOBS fmnist_2c2d (3,274,634 parameters)."""
    flat = (side // 4) ** 2 * 64
    return SequentialModel(
        "2c2d",
        [
            Conv2d(1, 32, 5, padding="SAME"), ReLU(),
            MaxPool2d(2, 2, "VALID"),
            Conv2d(32, 64, 5, padding="SAME"), ReLU(),
            MaxPool2d(2, 2, "VALID"),
            Flatten(),
            Linear(flat, 1024), ReLU(),
            Linear(1024, classes),
        ],
        CrossEntropyLoss(), (1, side, side), classes)


def _three_c3d_layers(last_act: Module):
    return [
        Conv2d(3, 64, 5, padding="VALID"), ReLU(),
        MaxPool2d(3, 2, "SAME"),
        Conv2d(64, 96, 3, padding="VALID"), ReLU(),
        MaxPool2d(3, 2, "SAME"),
        Conv2d(96, 128, 3, padding="SAME"), ReLU(),
        MaxPool2d(3, 2, "SAME"),
        Flatten(),
        Linear(1152, 512), ReLU(),
        Linear(512, 256), last_act,
        Linear(256, 10),
    ]


def three_c3d() -> SequentialModel:
    """DeepOBS cifar10_3c3d (895,210 parameters)."""
    return SequentialModel(
        "3c3d", _three_c3d_layers(ReLU()), CrossEntropyLoss(),
        (3, 32, 32), 10)


def three_c3d_sigmoid() -> SequentialModel:
    """Fig. 9 variant: one sigmoid before the last classification layer."""
    return SequentialModel(
        "3c3d_sigmoid", _three_c3d_layers(Sigmoid()), CrossEntropyLoss(),
        (3, 32, 32), 10)


def allcnnc(side: int = 32, classes: int = 100) -> SequentialModel:
    """All-CNN-C (Springenberg et al., 2015): 1,387,108 parameters,
    independent of ``side`` (fully convolutional)."""
    return SequentialModel(
        "allcnnc",
        [
            Conv2d(3, 96, 3, padding="SAME"), ReLU(),
            Conv2d(96, 96, 3, padding="SAME"), ReLU(),
            Conv2d(96, 96, 3, stride=2, padding="SAME"), ReLU(),
            Conv2d(96, 192, 3, padding="SAME"), ReLU(),
            Conv2d(192, 192, 3, padding="SAME"), ReLU(),
            Conv2d(192, 192, 3, stride=2, padding="SAME"), ReLU(),
            Conv2d(192, 192, 3, padding="VALID"), ReLU(),
            Conv2d(192, 192, 1, padding="VALID"), ReLU(),
            Conv2d(192, classes, 1, padding="VALID"), ReLU(),
            GlobalAvgPool2d(),
        ],
        CrossEntropyLoss(), (3, side, side), classes)


def mlp_tanh(in_dim=16, hidden=(12, 8), classes=4) -> SequentialModel:
    """Small tanh MLP used by tests (non-vanishing activation curvature
    exercises the Hessian-diagonal residual path)."""
    layers, d = [], in_dim
    for h in hidden:
        layers += [Linear(d, h), Tanh()]
        d = h
    layers += [Linear(d, classes)]
    return SequentialModel("mlp_tanh", layers, CrossEntropyLoss(),
                           (in_dim,), classes)


def mlp_sigmoid(in_dim=10, hidden=(8,), classes=3) -> SequentialModel:
    layers, d = [], in_dim
    for h in hidden:
        layers += [Linear(d, h), Sigmoid()]
        d = h
    layers += [Linear(d, classes)]
    return SequentialModel("mlp_sigmoid", layers, CrossEntropyLoss(),
                           (in_dim,), classes)


MODELS = {
    "logreg": logreg,
    "2c2d": two_c2d,
    "3c3d": three_c3d,
    "3c3d_sigmoid": three_c3d_sigmoid,
    "allcnnc": allcnnc,
}

#: Paper Table 3 parameter counts (the reproduction checksums).
PAPER_PARAM_COUNTS = {
    "logreg": 7_850,
    "2c2d": 3_274_634,
    "3c3d": 895_210,
    "allcnnc": 1_387_108,
}
