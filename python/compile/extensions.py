"""The BackPACK extension engine: generalized modular backpropagation.

``extended_backward`` runs ONE forward pass storing module inputs, then
walks the layer list backwards twice:

1. **first-order pass** (Fig. 4): propagates the per-sample output
   gradients ``g [N, *feat]`` (Eq. 3) and extracts, at every
   parameterized module, the averaged gradient plus any requested
   first-order quantity (individual gradients, their L2 norms, 2nd
   moment, variance -- Table 1 / Appendix A.1);

2. **second-order pass** (Fig. 5): propagates the symmetric loss-Hessian
   factorization ``S [N, *feat, C]`` (Eq. 18) -- exact (DiagGGN, KFLR),
   Monte-Carlo (DiagGGN-MC, KFAC) -- and/or the KFRA batch-averaged
   curvature ``Ḡ [h, h]`` (Eq. 24), and/or the Hessian-diagonal quantity
   list with the positive/negative residual factorizations of
   Appendix A.3.

All quantities follow Table 1's scaling conventions (the loss is the
*mean* over the batch):

====================  =====================================================
individual gradients  ``(1/N) ∇ℓ_n``
batch variance        ``1/N Σ [∇ℓ_n]² − [∇L]²``
2nd moment            ``1/N Σ [∇ℓ_n]²``
indiv. grad L2 norm   ``‖(1/N) ∇ℓ_n‖²``
DiagGGN(-MC)          ``diag(G(θ))``, ``G = 1/N Σ Jᵀ (∇²_f ℓ_n) J``
Hessian diagonal      ``diag(∇²_θ L)``
KFAC/KFLR/KFRA        ``G(θ^(i)) ≈ A^(i) ⊗ B^(i)``  (1/N inside factors)
====================  =====================================================

Everything here is pure JAX tracing code: it runs once, inside
``aot.py``, to produce the HLO artifacts the Rust runtime executes.
"""

from typing import Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp

from .kernels import ops
from .layers import _flat2

#: Extensions that reuse the standard backward pass (cheap, Fig. 4).
FIRST_ORDER = ("batch_grad", "batch_l2", "sq_moment", "variance")
#: Extensions that propagate extra information (Fig. 5).
SECOND_ORDER = ("diag_ggn", "diag_ggn_mc", "diag_h", "kfac", "kflr", "kfra")
ALL_EXTENSIONS = FIRST_ORDER + SECOND_ORDER


def _diag_embed_flat(r):
    """r [N, *feat] -> diagonal factor matrix [N, *feat, h] with
    h = prod(feat): the square root of diag(r) (r must be >= 0)."""
    n = r.shape[0]
    rf = _flat2(r)
    h = rf.shape[1]
    mat = jnp.sqrt(rf)[:, :, None] * jnp.eye(h, dtype=r.dtype)[None]
    return mat.reshape(r.shape + (h,))


def extended_backward(
    model,
    params: List[Dict],
    x,
    y,
    extensions: Sequence[str] = (),
    key=None,
    mc_samples: int = 1,
) -> Dict[str, jnp.ndarray]:
    """Run the generalized backward pass; returns {quantity_name: array}.

    Output keys: ``loss``, ``grad/{layer}/{param}``, and
    ``{extension}/{layer}/{param-or-factor}`` for each requested
    extension (see module docstring for conventions).
    """
    extensions = tuple(extensions)
    unknown = set(extensions) - set(ALL_EXTENSIONS)
    if unknown:
        raise ValueError(f"unknown extensions: {sorted(unknown)}")
    needs_mc = any(e in extensions for e in ("diag_ggn_mc", "kfac"))
    if needs_mc and key is None:
        raise ValueError("MC extensions require a PRNG key input")

    n = x.shape[0]
    out: Dict[str, jnp.ndarray] = {}

    # ---- forward pass, storing every module input (Fig. 2) ----------------
    acts = [x]
    h = x
    for layer, p in zip(model.layers, params):
        h = layer.forward(p, h)
        acts.append(h)
    logits = acts[-1]
    out["loss"] = model.loss.value(logits, y)

    # ---- first-order backward pass (Eq. 3 + Fig. 4) ------------------------
    g = model.loss.grad(logits, y)  # ∇_f ℓ_n, [N, C]
    grads_out = [None] * len(model.layers)  # ∇_{z^(i)} ℓ_n per layer
    for i in range(len(model.layers) - 1, -1, -1):
        layer, p, inp = model.layers[i], params[i], acts[i]
        grads_out[i] = g
        if layer.has_params:
            # The averaged gradient is always produced (optimizers need
            # it); per-sample gradients are materialized only when an
            # extension requires them anyway.
            bg = None
            if "batch_grad" in extensions:
                bg = layer.batch_grad(p, inp, g)
                for k, v in bg.items():
                    out[f"batch_grad/{i}/{k}"] = v / n
            if bg is not None:
                for k, v in bg.items():
                    out[f"grad/{i}/{k}"] = jnp.sum(v, axis=0) / n
            else:
                for k, v in _grad_param(layer, p, inp, g).items():
                    out[f"grad/{i}/{k}"] = v / n
            if "batch_l2" in extensions:
                for k, v in layer.batch_l2(p, inp, g).items():
                    out[f"batch_l2/{i}/{k}"] = v / (n * n)
            if "sq_moment" in extensions or "variance" in extensions:
                sq = {k: v / n for k, v in
                      layer.sq_moment(p, inp, g).items()}
                if "sq_moment" in extensions:
                    for k, v in sq.items():
                        out[f"sq_moment/{i}/{k}"] = v
                if "variance" in extensions:
                    for k, v in sq.items():
                        out[f"variance/{i}/{k}"] = v - out[f"grad/{i}/{k}"] ** 2
        if i > 0:
            g = layer.vjp_input(p, inp, g)

    # ---- second-order backward passes (Eq. 18 / Fig. 5) --------------------
    for ext, exact in (("diag_ggn", True), ("diag_ggn_mc", False)):
        if ext in extensions:
            s = _init_sqrt(model, logits, y, exact, key, mc_samples)
            _propagate_diag(model, params, acts, s, out, ext, n)

    for ext, exact in (("kflr", True), ("kfac", False)):
        if ext in extensions:
            s = _init_sqrt(model, logits, y, exact, key, mc_samples)
            _propagate_kron(model, params, acts, s, out, ext)

    if "kfra" in extensions:
        _propagate_kfra(model, params, acts, y, out)

    if "diag_h" in extensions:
        s = model.loss.sqrt_hessian(logits, y)
        _propagate_diag_h(model, params, acts, grads_out, s, out, n)

    return out


def _grad_param(layer, p, inp, g):
    """Averaged parameter gradient WITHOUT materializing per-sample
    gradients (sum over the batch; caller divides by N)."""
    from .layers import Conv2d, Linear

    if isinstance(layer, Linear):
        return {"w": ops.matmul_tn(g, inp), "b": jnp.sum(g, axis=0)}
    if isinstance(layer, Conv2d):
        pt = layer._patches(inp)                       # [N, I, T]
        g2 = g.reshape(g.shape[0], layer.cout, -1)     # [N, O, T]
        nt = pt.shape[0] * pt.shape[2]
        p2 = jnp.transpose(pt, (0, 2, 1)).reshape(nt, -1)
        g3 = jnp.transpose(g2, (0, 2, 1)).reshape(nt, -1)
        gw = ops.matmul_tn(g3, p2).reshape(p["w"].shape)
        return {"w": gw, "b": jnp.sum(g2, axis=(0, 2))}
    # fallback: per-sample then sum
    return {k: jnp.sum(v, axis=0)
            for k, v in layer.batch_grad(p, inp, g).items()}


def _init_sqrt(model, logits, y, exact: bool, key, mc_samples: int):
    if exact:
        return model.loss.sqrt_hessian(logits, y)          # [N, C, C]
    return model.loss.sqrt_hessian_mc(logits, y, key, mc_samples)


def _propagate_diag(model, params, acts, s, out, name, n):
    """DiagGGN / DiagGGN-MC: Eq. 18 propagation + Eq. 19 extraction."""
    for i in range(len(model.layers) - 1, -1, -1):
        layer, p, inp = model.layers[i], params[i], acts[i]
        if layer.has_params:
            for k, v in layer.diag_ggn(p, inp, s).items():
                out[f"{name}/{i}/{k}"] = v / n
        if i > 0:
            s = layer.mat_vjp_input(p, inp, s)


def _propagate_kron(model, params, acts, s, out, name):
    """KFAC / KFLR: same propagation, Kronecker-factor extraction."""
    for i in range(len(model.layers) - 1, -1, -1):
        layer, p, inp = model.layers[i], params[i], acts[i]
        if layer.has_params:
            for k, v in layer.kron_factors(p, inp, s).items():
                out[f"{name}/{i}/{k}"] = v
        if i > 0:
            s = layer.mat_vjp_input(p, inp, s)


def _propagate_kfra(model, params, acts, y, out):
    """KFRA: batch-averaged curvature propagation (Eq. 24).

    Only modules implementing ``avg_mat_vjp_input`` participate (Linear,
    activations, Flatten) -- matching the paper's own scope (footnote 5:
    KFRA's averaged backward does not scale to large convolutions)."""
    logits = acts[-1]
    gbar = model.loss.hessian_mean(logits, y)
    for i in range(len(model.layers) - 1, -1, -1):
        layer, p, inp = model.layers[i], params[i], acts[i]
        if layer.has_params:
            if not hasattr(layer, "kfra_factors"):
                raise NotImplementedError(
                    f"KFRA unsupported for {type(layer).__name__} "
                    "(paper footnote 5)")
            for k, v in layer.kfra_factors(p, inp, gbar).items():
                out[f"kfra/{i}/{k}"] = v
        if i > 0:
            gbar = layer.avg_mat_vjp_input(p, inp, gbar)


def _propagate_diag_h(model, params, acts, grads_out, s, out, n):
    """Exact Hessian diagonal (Appendix A.3).

    Propagates a LIST of signed square-root factors: the GGN part S plus,
    for every activation with non-vanishing second derivative, the
    positive/negative eigenspace factorizations P/N of the residual
    R = diag(σ''(x) ⊙ δ) (Eq. 25-26). The growth of this list -- and of
    the factor widths -- is exactly the cost explosion Fig. 9 measures."""
    quantities = [(s, 1.0)]  # (factor [N, *feat, K], sign)
    for i in range(len(model.layers) - 1, -1, -1):
        layer, p, inp = model.layers[i], params[i], acts[i]
        if layer.has_params:
            for mat, sign in quantities:
                for k, v in layer.diag_ggn(p, inp, mat).items():
                    key = f"diag_h/{i}/{k}"
                    out[key] = out.get(key, 0.0) + sign * v / n
        if i > 0:
            quantities = [(layer.mat_vjp_input(p, inp, mat), sign)
                          for mat, sign in quantities]
            r = layer.residual_diag(p, inp, grads_out[i])
            if r is not None:
                rpos, rneg = jnp.maximum(r, 0.0), jnp.maximum(-r, 0.0)
                quantities.append((_diag_embed_flat(rpos), 1.0))
                quantities.append((_diag_embed_flat(rneg), -1.0))
    return out


def evaluation(model, params, x, y):
    """Eval-graph payload: (mean loss, accuracy)."""
    logits = model.forward(params, x)
    return {
        "loss": model.loss.value(logits, y),
        "accuracy": model.loss.accuracy(logits, y),
    }
