"""Loss functions with the derivative interfaces BackPACK needs.

Each loss exposes, per sample (batch axis kept throughout):

* ``value``           -- mean loss over the batch (Eq. 1),
* ``grad``            -- ∇_f ℓ_n, the per-sample gradient w.r.t. the
                         network output (the *unnormalized* ∇ℓ_n; the
                         engine applies 1/N per Table 1's conventions),
* ``sqrt_hessian``    -- exact symmetric factorization S with
                         S Sᵀ = ∇²_f ℓ_n (Eq. 15; DiagGGN / KFLR),
* ``sqrt_hessian_mc`` -- rank-C̃ Monte-Carlo factorization S̃ with
                         E[S̃ S̃ᵀ] = ∇²_f ℓ_n (Eq. 20–21; DiagGGN-MC /
                         KFAC),
* ``hessian_mean``    -- 1/N Σ_n ∇²_f ℓ_n (Eq. 24b; KFRA's Ḡ^(L)).

Cross-entropy factorization: with p = softmax(f),
``H = diag(p) − p pᵀ = S Sᵀ`` for ``S = diag(√p) − p √pᵀ`` (exact, C×C).
MC sampling (Martens & Grosse 2015): ŷ ~ Cat(p), s̃ = p − e_ŷ, since
``E[s̃ s̃ᵀ] = diag(p) − p pᵀ``.
"""

import jax
import jax.numpy as jnp


class CrossEntropyLoss:
    """Softmax cross-entropy, mean over the batch."""

    def value(self, logits, y):
        logp = jax.nn.log_softmax(logits, axis=-1)
        nll = -jnp.take_along_axis(logp, y[:, None], axis=1)[:, 0]
        return jnp.mean(nll)

    def per_sample(self, logits, y):
        logp = jax.nn.log_softmax(logits, axis=-1)
        return -jnp.take_along_axis(logp, y[:, None], axis=1)[:, 0]

    def grad(self, logits, y):
        p = jax.nn.softmax(logits, axis=-1)
        onehot = jax.nn.one_hot(y, logits.shape[-1], dtype=logits.dtype)
        return p - onehot

    def sqrt_hessian(self, logits, y):
        p = jax.nn.softmax(logits, axis=-1)              # [N, C]
        sqrtp = jnp.sqrt(p)
        return (jnp.eye(p.shape[-1])[None] * sqrtp[:, None, :]
                - p[:, :, None] * sqrtp[:, None, :])     # [N, C, C]

    def sqrt_hessian_mc(self, logits, y, key, samples: int = 1):
        p = jax.nn.softmax(logits, axis=-1)
        n, c = logits.shape
        yhat = jax.random.categorical(
            key, jnp.log(p + 1e-30)[:, None, :].repeat(samples, axis=1),
            axis=-1)                                     # [N, M]
        onehot = jax.nn.one_hot(yhat, c, dtype=logits.dtype)  # [N, M, C]
        s = (p[:, None, :] - onehot) / jnp.sqrt(float(samples))
        return jnp.transpose(s, (0, 2, 1))               # [N, C, M]

    def hessian_mean(self, logits, y):
        p = jax.nn.softmax(logits, axis=-1)
        h = (jnp.eye(p.shape[-1])[None] * p[:, None, :]
             - p[:, :, None] * p[:, None, :])
        return jnp.mean(h, axis=0)

    def accuracy(self, logits, y):
        return jnp.mean((jnp.argmax(logits, axis=-1) == y).astype(
            jnp.float32))


class MSELoss:
    """``mean_n |f_n − y_n|²`` (DeepOBS regression convention).

    Per-sample Hessian w.r.t. f is 2I, so S = √2·I and the MC
    factorization samples s̃ = √2 ε, ε ~ N(0, I) (E[s̃s̃ᵀ] = 2I)."""

    def value(self, logits, y):
        return jnp.mean(jnp.sum((logits - y) ** 2, axis=-1))

    def per_sample(self, logits, y):
        return jnp.sum((logits - y) ** 2, axis=-1)

    def grad(self, logits, y):
        return 2.0 * (logits - y)

    def sqrt_hessian(self, logits, y):
        n, c = logits.shape
        return jnp.broadcast_to(
            jnp.sqrt(2.0) * jnp.eye(c)[None], (n, c, c)).astype(logits.dtype)

    def sqrt_hessian_mc(self, logits, y, key, samples: int = 1):
        n, c = logits.shape
        eps = jax.random.normal(key, (n, c, samples), logits.dtype)
        return jnp.sqrt(2.0 / samples) * eps

    def hessian_mean(self, logits, y):
        return 2.0 * jnp.eye(logits.shape[-1], dtype=logits.dtype)

    def accuracy(self, logits, y):
        return jnp.mean((jnp.argmax(logits, -1) == jnp.argmax(y, -1))
                        .astype(jnp.float32))
