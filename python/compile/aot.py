"""AOT pipeline: lower every (problem x extension-set) graph to HLO text.

``python -m compile.aot --out-dir ../artifacts`` writes one
``<name>.hlo.txt`` per artifact plus ``manifest.json`` describing, for
each artifact, the exact input order (parameters in layer order, then
``x``, ``y`` and -- for Monte-Carlo extensions -- a ``key`` of raw
threefry key data), the output order (sorted quantity names), shapes,
dtypes and parameter-initialization metadata, so the Rust runtime is
fully self-describing.

This is the ONLY place Python runs: once, at build time. The build is
incremental -- a content hash over the compile/ sources and the artifact
spec table is stored in the manifest and the build is skipped when it
matches (``--force`` overrides; ``--only REGEX`` restricts to matching
artifact names).

Artifact inventory (see DESIGN.md §5 for the per-figure mapping):

* training graphs for the four DeepOBS problems of Table 3, one per
  curvature (grad-only / DiagGGN / DiagGGN-MC / KFAC / KFLR / KFRA);
* evaluation graphs (loss + accuracy at larger batches);
* overhead-benchmark graphs for Fig. 3 (batch-size sweep incl. the
  batch-1 for-loop baseline), Fig. 6 (one artifact per extension),
  Fig. 8 (exact-matrix propagation on the C=100 net) and Fig. 9
  (Hessian diagonal with a sigmoid);
* a combined first-order artifact (quickstart / gradient-noise example).
"""

import argparse
import hashlib
import json
import pathlib
import re
import sys

import jax
import jax.numpy as jnp

from . import models as M
from .extensions import evaluation, extended_backward
from .hlo_util import lower_to_hlo_text
from .layers import Conv2d, Linear

_COMPILE_DIR = pathlib.Path(__file__).parent


# ---------------------------------------------------------------------------
# Artifact specification table
# ---------------------------------------------------------------------------


def _mk_model(model_name: str, side: int):
    if model_name == "allcnnc":
        return M.allcnnc(side=side)
    return M.MODELS[model_name]()


def spec_table():
    """[(name, model_name, side, batch, extensions, kind)]"""
    specs = []

    def add(model, side, n, exts, kind="train"):
        sig = "grad" if not exts else "+".join(exts)
        if kind == "eval":
            sig = "eval"
        name = f"{model}{side if model == 'allcnnc' else ''}_{sig}_n{n}"
        row = (name, model, side, n, tuple(exts), kind)
        if row not in specs:
            specs.append(row)

    # -- training graphs (Figs. 7, 10, 11; Table 4) --------------------------
    for ext in ([], ["diag_ggn"], ["diag_ggn_mc"], ["kfac"], ["kflr"],
                ["kfra"]):
        add("logreg", 0, 64, ext)
    for model, n in (("2c2d", 32), ("3c3d", 32)):
        for ext in ([], ["diag_ggn"], ["diag_ggn_mc"], ["kfac"], ["kflr"]):
            add(model, 0, n, ext)
    for ext in ([], ["diag_ggn_mc"], ["kfac"]):
        add("allcnnc", 16, 16, ext)

    # -- evaluation graphs ----------------------------------------------------
    add("logreg", 0, 256, [], kind="eval")
    add("2c2d", 0, 128, [], kind="eval")
    add("3c3d", 0, 128, [], kind="eval")
    add("allcnnc", 16, 64, [], kind="eval")

    # -- Fig. 6: per-extension overhead, N=64 (3c3d) / N=16 (allcnnc 32x32) --
    for ext in (["batch_grad"], ["batch_l2"], ["sq_moment"], ["variance"],
                ["diag_ggn"], ["diag_ggn_mc"], ["kfac"], ["kflr"], []):
        add("3c3d", 0, 64, ext)
    for ext in (["batch_grad"], ["batch_l2"], ["sq_moment"], ["variance"],
                ["diag_ggn_mc"], ["kfac"], []):
        add("allcnnc", 32, 16, ext)

    # -- Fig. 3: individual gradients, batch-size sweep ----------------------
    for n in (1, 4, 16, 32):
        add("3c3d", 0, n, [])
    for n in (4, 16, 32):
        add("3c3d", 0, n, ["batch_grad"])

    # -- Fig. 8: full-matrix propagation on the C=100 output -----------------
    for ext in (["kflr"], ["diag_ggn"], ["kfac"], ["diag_ggn_mc"], []):
        add("allcnnc", 32, 8, ext)

    # -- Fig. 9: Hessian diagonal vs GGN diagonal with one sigmoid -----------
    for ext in (["diag_h"], ["diag_ggn"], []):
        add("3c3d_sigmoid", 0, 8, ext)

    # -- combined first-order artifacts (quickstart, noise-scale example) ----
    add("logreg", 0, 64, ["batch_grad", "batch_l2", "sq_moment",
                          "variance"])
    add("3c3d", 0, 32, ["batch_l2", "variance"])
    return specs


# ---------------------------------------------------------------------------
# Lowering
# ---------------------------------------------------------------------------

_DTYPES = {jnp.float32.dtype: "f32", jnp.int32.dtype: "i32",
           jnp.uint32.dtype: "u32"}


def _param_entries(model, params):
    """Manifest input records for parameters, with init metadata."""
    entries = []
    for i, (layer, p) in enumerate(zip(model.layers, params)):
        for pname in layer.param_names:
            arr = p[pname]
            if pname == "b":
                init = {"kind": "zeros"}
            elif isinstance(layer, Linear):
                init = {"kind": "uniform", "bound":
                        1.0 / layer.in_features ** 0.5}
            elif isinstance(layer, Conv2d):
                init = {"kind": "uniform", "bound":
                        1.0 / (layer.cin * layer.k * layer.k) ** 0.5}
            else:
                raise AssertionError(type(layer))
            entries.append({
                "name": f"param/{i}/{pname}",
                "shape": list(arr.shape),
                "dtype": "f32",
                "init": init,
            })
    return entries


def build_artifact(name, model_name, side, n, exts, kind, out_dir):
    model = _mk_model(model_name, side)
    params = jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))
    flat_names = [(i, pn) for i, l in enumerate(model.layers)
                  for pn in l.param_names]
    has_key = any(e in ("diag_ggn_mc", "kfac") for e in exts)

    x_spec = jax.ShapeDtypeStruct((n,) + model.in_shape, jnp.float32)
    y_spec = jax.ShapeDtypeStruct((n,), jnp.int32)
    key_spec = jax.ShapeDtypeStruct((2,), jnp.uint32)
    p_specs = [jax.ShapeDtypeStruct(params[i][pn].shape, jnp.float32)
               for i, pn in flat_names]

    def unflatten(args):
        ps = [dict() for _ in model.layers]
        for (i, pn), a in zip(flat_names, args):
            ps[i][pn] = a
        return ps

    def fn_dict(*args):
        ps = unflatten(args[:len(flat_names)])
        x, y = args[len(flat_names)], args[len(flat_names) + 1]
        if kind == "eval":
            return evaluation(model, ps, x, y)
        key = (jax.random.wrap_key_data(args[-1]) if has_key else None)
        return extended_backward(model, ps, x, y, exts, key=key)

    example = tuple(p_specs) + (x_spec, y_spec) + (
        (key_spec,) if has_key else ())
    out_shapes = jax.eval_shape(fn_dict, *example)
    out_names = sorted(out_shapes.keys())

    def fn_tuple(*args):
        d = fn_dict(*args)
        return tuple(d[k] for k in out_names)

    text = lower_to_hlo_text(fn_tuple, example)
    (out_dir / f"{name}.hlo.txt").write_text(text)

    inputs = _param_entries(model, params)
    inputs.append({"name": "x", "shape": list(x_spec.shape),
                   "dtype": "f32"})
    inputs.append({"name": "y", "shape": list(y_spec.shape),
                   "dtype": "i32"})
    if has_key:
        inputs.append({"name": "key", "shape": [2], "dtype": "u32"})
    outputs = [{"name": k, "shape": list(out_shapes[k].shape),
                "dtype": _DTYPES[out_shapes[k].dtype]}
               for k in out_names]
    return {
        "file": f"{name}.hlo.txt",
        "model": model_name, "side": side, "batch_size": n,
        "extensions": list(exts), "kind": kind, "has_key": has_key,
        "num_classes": model.num_classes,
        "in_shape": list(model.in_shape),
        "inputs": inputs, "outputs": outputs,
    }


def source_hash() -> str:
    h = hashlib.sha256()
    for f in sorted(_COMPILE_DIR.rglob("*.py")):
        h.update(f.read_bytes())
    h.update(repr(spec_table()).encode())
    return h.hexdigest()


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--only", default=None,
                    help="regex restricting artifact names to rebuild")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--list", action="store_true",
                    help="print the artifact table and exit")
    args = ap.parse_args(argv)

    specs = spec_table()
    if args.list:
        for row in specs:
            print(row[0])
        return

    out_dir = pathlib.Path(args.out_dir)
    out_dir.mkdir(parents=True, exist_ok=True)
    manifest_path = out_dir / "manifest.json"
    shash = source_hash()

    manifest = {"artifacts": {}, "source_hash": None}
    if manifest_path.exists():
        manifest = json.loads(manifest_path.read_text())
    up_to_date = (
        manifest.get("source_hash") == shash
        and all((out_dir / a["file"]).exists()
                for a in manifest["artifacts"].values())
        and set(manifest["artifacts"]) == {s[0] for s in specs})
    if up_to_date and not args.force and not args.only:
        print(f"artifacts up to date ({len(specs)} graphs), skipping")
        return

    pat = re.compile(args.only) if args.only else None
    for name, model_name, side, n, exts, kind in specs:
        if pat and not pat.search(name):
            continue
        reuse = (not args.force and manifest.get("source_hash") == shash
                 and name in manifest["artifacts"]
                 and (out_dir / f"{name}.hlo.txt").exists())
        if reuse:
            print(f"  [cached] {name}")
            continue
        print(f"  [lower]  {name} ...", flush=True)
        manifest["artifacts"][name] = build_artifact(
            name, model_name, side, n, exts, kind, out_dir)
    manifest["source_hash"] = shash
    manifest_path.write_text(json.dumps(manifest, indent=1))
    print(f"wrote {manifest_path} ({len(manifest['artifacts'])} artifacts)")


if __name__ == "__main__":
    main()
