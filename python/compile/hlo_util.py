"""Lowering helpers: jitted jax function -> HLO *text*.

HLO text (NOT ``lowered.compile().serialize()`` / serialized
HloModuleProto) is the interchange format: jax >= 0.5 emits protos with
64-bit instruction ids which xla_extension 0.5.1 (the version the
published `xla` 0.1.6 crate links) rejects (``proto.id() <= INT_MAX``).
The text parser on the Rust side reassigns ids, so text round-trips
cleanly. See /opt/xla-example/README.md.
"""

import jax
from jax._src.lib import xla_client as xc


def lower_to_hlo_text(fn, example_args) -> str:
    """Lower ``fn`` at the given abstract args and return HLO text.

    The computation is built with ``return_tuple=True`` so the Rust side
    always unwraps a tuple (uniform handling of multi-output graphs).
    """
    lowered = jax.jit(fn).lower(*example_args)
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True)
    return comp.as_hlo_text()
