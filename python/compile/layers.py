"""Layer-2 modular feed-forward framework (the paper's Sec. 2 setting).

Every layer is a module ``T^(i)`` that knows how to

* run its forward transformation (Eq. 2),
* apply its **transposed Jacobians** -- w.r.t. the input (``vjp_input``,
  the backprop recursion of Eq. 3) and w.r.t. its parameters
  (``batch_grad`` & friends, Eq. 5), always keeping the batch axis, and
* propagate **matrix-shaped** quantities: the symmetric GGN
  factorization ``S [N, *out, C]`` (``mat_vjp_input``, Eq. 18) and the
  KFRA batch-averaged curvature ``Ḡ [h, h]`` (``avg_mat_vjp_input``,
  Eq. 24a).

This is the "generalized backpropagation" the paper builds: the engine in
:mod:`extensions` walks the layer list backwards and calls these hooks.
``jax.grad`` is never used on the model -- only inside ``Conv2d``/pooling
modules, module-locally, as the Jacobian application of that single
transformation (a module "knows how to multiply with its Jacobian").

Extraction hot spots call the L1 Pallas kernels (:mod:`kernels.ops`).

Shape conventions: activations are ``[N, features]`` or ``[N, C, H, W]``;
parameters follow PyTorch (``Linear: w [out, in], b [out]``;
``Conv2d: w [cout, cin, kh, kw], b [cout]``); weight and bias are separate
parameters/blocks (paper footnote 7).
"""

import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from .kernels import ops


Params = Dict[str, jnp.ndarray]


def _flat2(x):
    """[N, ...] -> [N, prod(...)]."""
    return x.reshape(x.shape[0], -1)


def _smat(s):
    """S [N, *feat, C] -> [N, prod(feat), C]."""
    return s.reshape(s.shape[0], -1, s.shape[-1])


class Module:
    """Base module. Stateless; parameters travel as dicts of arrays."""

    #: parameter names in canonical order ("w", "b") or () for none.
    param_names: Tuple[str, ...] = ()

    def init(self, key, in_shape):
        """Return (params, out_shape). ``in_shape`` excludes the batch dim."""
        raise NotImplementedError

    def forward(self, params: Params, x):
        raise NotImplementedError

    # -- first-order hooks ---------------------------------------------------
    def vjp_input(self, params: Params, x, g):
        """Apply (J_x z)^T per sample: g [N, *out] -> [N, *in] (Eq. 3)."""
        raise NotImplementedError

    def batch_grad(self, params: Params, x, g) -> Params:
        """Per-sample parameter gradients {name: [N, *pshape]} (Eq. 5)."""
        raise NotImplementedError

    def batch_l2(self, params: Params, x, g) -> Params:
        """Per-sample squared L2 norms {name: [N]} without materializing
        the individual gradients where the Jacobian structure allows
        (Appx A.1)."""
        bg = self.batch_grad(params, x, g)
        return {k: jnp.sum(_flat2(v) ** 2, axis=1) for k, v in bg.items()}

    def sq_moment(self, params: Params, x, g) -> Params:
        """Sum over the batch of squared per-sample gradients
        {name: [*pshape]} (Appx A.1; caller applies the 1/N)."""
        bg = self.batch_grad(params, x, g)
        return {k: jnp.sum(v**2, axis=0) for k, v in bg.items()}

    # -- second-order hooks --------------------------------------------------
    def mat_vjp_input(self, params: Params, x, s):
        """Apply (J_x z)^T columnwise: S [N, *out, C] -> [N, *in, C]
        (Eq. 18). Default: vmap the vjp over the factorization columns."""
        def vjp_one(col):
            return self.vjp_input(params, x, col)
        return jax.vmap(vjp_one, in_axes=-1, out_axes=-1)(s)

    def diag_ggn(self, params: Params, x, s) -> Params:
        """Sum over batch of diag([J_θ z]^T S S^T [J_θ z]) per parameter
        (Eq. 19; caller applies the 1/N)."""
        raise NotImplementedError

    def kron_factors(self, params: Params, x, s):
        """Kronecker factors for this layer (Eq. 23): returns a dict with
        'A' [a, a], 'B' [b, b] (weight block ≈ A ⊗ B) and 'bias_ggn'
        [b, b] (the bias block's full GGN, paper footnote 7/8)."""
        raise NotImplementedError

    def avg_mat_vjp_input(self, params: Params, x, gbar):
        """KFRA averaged propagation (Eq. 24a): Ḡ [h_out, h_out] ->
        [h_in, h_in]."""
        raise NotImplementedError

    def residual_diag(self, params: Params, x, g) -> Optional[jnp.ndarray]:
        """Diagonal residual r [N, *in] of Eq. 25b (second derivative of
        the transformation times incoming gradient). None when zero."""
        return None

    @property
    def has_params(self) -> bool:
        return bool(self.param_names)


# ---------------------------------------------------------------------------
# Linear
# ---------------------------------------------------------------------------


class Linear(Module):
    """Affine map ``z = x W^T + b`` with W [out, in], b [out]."""

    param_names = ("w", "b")

    def __init__(self, in_features: int, out_features: int):
        self.in_features = in_features
        self.out_features = out_features

    def init(self, key, in_shape):
        assert in_shape == (self.in_features,), in_shape
        kw, _ = jax.random.split(key)
        bound = 1.0 / math.sqrt(self.in_features)
        w = jax.random.uniform(
            kw, (self.out_features, self.in_features), jnp.float32,
            -bound, bound)
        b = jnp.zeros((self.out_features,), jnp.float32)
        return {"w": w, "b": b}, (self.out_features,)

    def forward(self, params, x):
        return x @ params["w"].T + params["b"]

    def vjp_input(self, params, x, g):
        return g @ params["w"]

    def batch_grad(self, params, x, g):
        return {"w": ops.outer_batch(g, x), "b": g}

    def batch_l2(self, params, x, g):
        return {"w": ops.batch_l2(g, x), "b": jnp.sum(g**2, axis=1)}

    def sq_moment(self, params, x, g):
        return {"w": ops.sq_moment(g, x), "b": jnp.sum(g**2, axis=0)}

    def mat_vjp_input(self, params, x, s):
        # [N, out, C] x [out, in] -> [N, in, C]
        return jnp.einsum("noc,oi->nic", s, params["w"])

    def diag_ggn(self, params, x, s):
        return {
            "w": ops.diag_ggn_from_sqrt(s, x),
            "b": jnp.sum(ops.sq_reduce(s), axis=0),
        }

    def kron_factors(self, params, x, s):
        bias_ggn = ops.kron_factor_B(s)  # 1/N sum_n S S^T  [out, out]
        return {
            "A": ops.kron_factor_A(x),  # 1/N sum_n x x^T  [in, in]
            "B": bias_ggn,
            "bias_ggn": bias_ggn,
        }

    def avg_mat_vjp_input(self, params, x, gbar):
        w = params["w"]
        return w.T @ gbar @ w

    def kfra_factors(self, params, x, gbar):
        return {"A": ops.kron_factor_A(x), "B": gbar, "bias_ggn": gbar}


# ---------------------------------------------------------------------------
# Conv2d (reduced to the linear case by patch extraction / im2col,
# following Grosse & Martens 2016 -- see DESIGN.md §6)
# ---------------------------------------------------------------------------

_DN = ("NCHW", "OIHW", "NCHW")


class Conv2d(Module):
    """2-D convolution, NCHW, weight [cout, cin, kh, kw], bias [cout]."""

    param_names = ("w", "b")

    def __init__(self, cin, cout, ksize, stride=1, padding="SAME"):
        self.cin, self.cout, self.k = cin, cout, ksize
        self.stride = stride
        self.padding = padding  # "SAME" | "VALID"

    def init(self, key, in_shape):
        c, h, w = in_shape
        assert c == self.cin, (in_shape, self.cin)
        fan_in = self.cin * self.k * self.k
        bound = 1.0 / math.sqrt(fan_in)
        kw, _ = jax.random.split(key)
        weight = jax.random.uniform(
            kw, (self.cout, self.cin, self.k, self.k), jnp.float32,
            -bound, bound)
        bias = jnp.zeros((self.cout,), jnp.float32)
        out_shape = jax.eval_shape(
            lambda t: self._conv(t, weight),
            jax.ShapeDtypeStruct((1, c, h, w), jnp.float32)).shape[1:]
        return {"w": weight, "b": bias}, out_shape

    def _conv(self, x, w):
        return lax.conv_general_dilated(
            x, w, (self.stride, self.stride), self.padding,
            dimension_numbers=_DN)

    def forward(self, params, x):
        return self._conv(x, params["w"]) + params["b"][None, :, None, None]

    def _patches(self, x):
        """Unfolded input [N, cin*k*k, T] with T = H'·W'; feature ordering
        matches ``w.reshape(cout, cin*k*k)`` (verified by tests)."""
        p = lax.conv_general_dilated_patches(
            x, (self.k, self.k), (self.stride, self.stride), self.padding)
        return p.reshape(p.shape[0], p.shape[1], -1)

    def vjp_input(self, params, x, g):
        # Module-local Jacobian application via the conv transpose rule.
        _, vjp = jax.vjp(lambda t: self._conv(t, params["w"]), x)
        return vjp(g)[0]

    def batch_grad(self, params, x, g):
        p = self._patches(x)                         # [N, I, T]
        g2 = _flat2(g).reshape(g.shape[0], self.cout, -1)  # [N, O, T]
        gw = jnp.einsum("not,nit->noi", g2, p)
        return {
            "w": gw.reshape(g.shape[0], *params["w"].shape),
            "b": jnp.sum(g2, axis=2),
        }

    def sq_moment(self, params, x, g):
        bg = self.batch_grad(params, x, g)
        return {k: jnp.sum(v**2, axis=0) for k, v in bg.items()}

    def mat_vjp_input(self, params, x, s):
        def vjp_one(col):
            return self.vjp_input(params, x, col)
        return jax.vmap(vjp_one, in_axes=-1, out_axes=-1)(s)

    def diag_ggn(self, params, x, s):
        # s [N, cout, H', W', C];  J_w z = patches:
        # diag_w[o,i] = sum_{n,c} (sum_t p[n,i,t] s[n,o,t,c])^2
        n = s.shape[0]
        p = self._patches(x)                                  # [N, I, T]
        sm = s.reshape(n, self.cout, -1, s.shape[-1])         # [N, O, T, C]
        js = jnp.einsum("nit,notc->noic", p, sm)              # [N, O, I, C]
        dw = jnp.sum(js**2, axis=(0, 3))
        sb = jnp.sum(sm, axis=2)                              # [N, O, C]
        db = jnp.sum(ops.sq_reduce(sb), axis=0)
        return {"w": dw.reshape(params["w"].shape), "b": db}

    def kron_factors(self, params, x, s):
        # Grosse & Martens (2016) convolution factors; see DESIGN.md §6.
        n = s.shape[0]
        p = self._patches(x)                                  # [N, I, T]
        t = p.shape[-1]
        p2 = jnp.transpose(p, (0, 2, 1)).reshape(n * t, -1)   # [(N T), I]
        a = ops.matmul_tn(p2, p2) / n                         # sum over t
        sm = s.reshape(n, self.cout, -1, s.shape[-1])         # [N, O, T, C]
        s2 = jnp.transpose(sm, (0, 2, 3, 1)).reshape(-1, self.cout)
        b = ops.matmul_tn(s2, s2) / (n * t)
        sb = jnp.sum(sm, axis=2)                              # [N, O, C]
        bias_ggn = ops.kron_factor_B(sb)
        return {"A": a, "B": b, "bias_ggn": bias_ggn}


# ---------------------------------------------------------------------------
# Elementwise activations
# ---------------------------------------------------------------------------


class Activation(Module):
    """Elementwise activation; subclasses define σ, σ', σ''."""

    def act(self, x):
        raise NotImplementedError

    def d_act(self, x):
        raise NotImplementedError

    def d2_act(self, x):
        raise NotImplementedError

    def init(self, key, in_shape):
        return {}, in_shape

    def forward(self, params, x):
        return self.act(x)

    def vjp_input(self, params, x, g):
        return self.d_act(x) * g

    def mat_vjp_input(self, params, x, s):
        return self.d_act(x)[..., None] * s

    def avg_mat_vjp_input(self, params, x, gbar):
        # Ḡ' = 1/N Σ diag(m_n) Ḡ diag(m_n) = Ḡ ∘ (1/N Σ m_n m_nᵀ)
        m = _flat2(self.d_act(x))
        return gbar * (ops.matmul_tn(m, m) / m.shape[0])

    def residual_diag(self, params, x, g):
        """r = σ''(x) ⊙ δ_out (Appx A.3); None for piecewise-linear σ."""
        d2 = self.d2_act(x)
        return d2 * g


class ReLU(Activation):
    def act(self, x):
        return jnp.maximum(x, 0.0)

    def d_act(self, x):
        return (x > 0).astype(x.dtype)

    def d2_act(self, x):
        return jnp.zeros_like(x)

    def residual_diag(self, params, x, g):
        return None  # piecewise linear: exactly zero a.e.


class Sigmoid(Activation):
    def act(self, x):
        return jax.nn.sigmoid(x)

    def d_act(self, x):
        s = jax.nn.sigmoid(x)
        return s * (1 - s)

    def d2_act(self, x):
        s = jax.nn.sigmoid(x)
        return s * (1 - s) * (1 - 2 * s)


class Tanh(Activation):
    def act(self, x):
        return jnp.tanh(x)

    def d_act(self, x):
        return 1 - jnp.tanh(x) ** 2

    def d2_act(self, x):
        t = jnp.tanh(x)
        return -2 * t * (1 - t**2)


# ---------------------------------------------------------------------------
# Shape / pooling layers (parameter-free)
# ---------------------------------------------------------------------------


class Flatten(Module):
    def init(self, key, in_shape):
        self._in_shape = in_shape
        return {}, (math.prod(in_shape),)

    def forward(self, params, x):
        return _flat2(x)

    def vjp_input(self, params, x, g):
        return g.reshape(x.shape)

    def mat_vjp_input(self, params, x, s):
        return s.reshape(x.shape + (s.shape[-1],))

    def avg_mat_vjp_input(self, params, x, gbar):
        return gbar

    def residual_diag(self, params, x, g):
        return None


class MaxPool2d(Module):
    def __init__(self, ksize, stride, padding="SAME"):
        self.k, self.stride, self.padding = ksize, stride, padding

    def init(self, key, in_shape):
        out = jax.eval_shape(
            lambda t: self.forward({}, t),
            jax.ShapeDtypeStruct((1,) + tuple(in_shape), jnp.float32))
        return {}, out.shape[1:]

    def forward(self, params, x):
        return lax.reduce_window(
            x, -jnp.inf, lax.max,
            (1, 1, self.k, self.k), (1, 1, self.stride, self.stride),
            self.padding)

    def vjp_input(self, params, x, g):
        # Module-local Jacobian application: routes g to the argmax
        # positions (the max-pool Jacobian is a 0/1 selection matrix).
        _, vjp = jax.vjp(lambda t: self.forward({}, t), x)
        return vjp(g)[0]

    def residual_diag(self, params, x, g):
        return None  # piecewise linear


class GlobalAvgPool2d(Module):
    """[N, C, H, W] -> [N, C] mean over spatial positions (All-CNN-C)."""

    def init(self, key, in_shape):
        c, h, w = in_shape
        self._hw = h * w
        return {}, (c,)

    def forward(self, params, x):
        return jnp.mean(x, axis=(2, 3))

    def vjp_input(self, params, x, g):
        n, c, h, w = x.shape
        return jnp.broadcast_to(
            g[:, :, None, None] / (h * w), x.shape)

    def mat_vjp_input(self, params, x, s):
        n, c, h, w = x.shape
        return jnp.broadcast_to(
            s[:, :, None, None, :] / (h * w), (n, c, h, w, s.shape[-1]))

    def residual_diag(self, params, x, g):
        return None
