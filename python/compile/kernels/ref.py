"""Pure-jnp oracles for the Pallas extraction kernels.

These are the correctness ground truth: every Pallas kernel is checked
against its oracle by pytest/hypothesis sweeps (python/tests/
test_kernels.py). They are also a selectable backend
(``BACKPACK_KERNELS=jnp``) used by the kernel-backend ablation bench.
"""

import jax.numpy as jnp


def matmul_tn_ref(p, q):
    """``out[b, a] = sum_n p[n, b] q[n, a]``."""
    return jnp.einsum("nb,na->ba", p, q)


def outer_batch_ref(g, x):
    """``out[n, b, a] = g[n, b] x[n, a]`` (per-sample gradients)."""
    return jnp.einsum("nb,na->nba", g, x)


def batch_l2_ref(g, x):
    """``out[n] = |g_n|^2 |x_n|^2`` (squared Frobenius norm of g_n x_n^T)."""
    return jnp.sum(g * g, axis=1) * jnp.sum(x * x, axis=1)


def sq_reduce_ref(s):
    """``out[n, b] = sum_c s[n, b, c]^2``."""
    return jnp.sum(s * s, axis=2)
