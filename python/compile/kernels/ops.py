"""Backend-dispatching extraction ops used by the extension engine (L2).

Every BackPACK quantity's inner loop funnels through these functions;
``BACKPACK_KERNELS`` selects Pallas vs. pure-jnp (see package docstring).
The higher-level compositions (2nd moment, GGN diagonal, Kronecker
factors) live here so both backends share one algebra.
"""

import jax.numpy as jnp

from . import KERNEL_TARGET, ref, use_pallas
from . import pallas_impl as pk


def matmul_tn(p, q):
    """``einsum('nb,na->ba')`` -- batch-reduced contraction."""
    if use_pallas():
        return pk.matmul_tn_pallas(p, q, target=KERNEL_TARGET)
    return ref.matmul_tn_ref(p, q)


def outer_batch(g, x):
    """``einsum('nb,na->nba')`` -- per-sample weight gradients (Eq. 5)."""
    if use_pallas():
        return pk.outer_batch_pallas(g, x, target=KERNEL_TARGET)
    return ref.outer_batch_ref(g, x)


def batch_l2(g, x):
    """Per-sample squared L2 norms of linear-layer gradients (Appx A.1)."""
    if use_pallas():
        return pk.batch_l2_pallas(g, x, target=KERNEL_TARGET)
    return ref.batch_l2_ref(g, x)


def sq_reduce(s):
    """``sum_c S[n, b, c]^2`` -- diagonal extraction step (Eq. 19)."""
    if use_pallas():
        return pk.sq_reduce_pallas(s, target=KERNEL_TARGET)
    return ref.sq_reduce_ref(s)


# -- compositions ------------------------------------------------------------


def sq_moment(g, x):
    """2nd moment of a linear layer's weight gradient (Appx A.1).

    ``out[b, a] = sum_n (g[n,b] x[n,a])^2 = (g^2)^T (x^2)``.
    """
    return matmul_tn(g * g, x * x)


def diag_ggn_from_sqrt(s, x):
    """GGN diagonal of a linear layer's weight from the backpropagated
    factorization ``S [N, B, C]`` and layer input ``x [N, A]`` (Eq. 19):

    ``diag[b, a] = sum_n x[n,a]^2 * sum_c S[n,b,c]^2``.
    """
    return matmul_tn(sq_reduce(s), x * x)


def kron_factor_A(x):
    """First Kronecker factor ``A = 1/N sum_n x_n x_n^T`` (Eq. 23)."""
    n = x.shape[0]
    return matmul_tn(x, x) / n


def kron_factor_B(s):
    """Second Kronecker factor ``B = 1/N sum_n S_n S_n^T`` from
    ``S [N, B, C]`` (KFAC/KFLR, Appx A.2.2)."""
    n, b, c = s.shape
    s2d = jnp.transpose(s, (0, 2, 1)).reshape(n * c, b)
    return matmul_tn(s2d, s2d) / n
