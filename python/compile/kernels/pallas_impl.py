"""Pallas implementations of the four extraction primitives.

All kernels are written for TPU tiling discipline (MXU-shaped blocks,
reduction as the innermost sequential grid dimension, accumulation into
the output block) but executed with ``interpret=True`` so the CPU PJRT
backend can run the lowered HLO (see /opt/xla-example README: real TPU
lowering emits Mosaic custom-calls the CPU plugin rejects).

Block-shape policy (:func:`block_plan`):

- ``tpu``  -- 128-aligned tiles; the working set of the matmul kernel is
  ``bn*bb + bn*ba + bb*ba`` f32 which with (512, 128, 128) is ~0.6 MB,
  comfortably double-bufferable in 16 MB VMEM.
- ``cpu``  -- blocks grow to the (padded) full dimension, capped so a
  block stays under ~32 MB; fewer grid steps = less interpret overhead.

Inputs whose dimensions are not multiples of the block size are
zero-padded by the wrappers here; zero padding is exact for every kernel
(all are polynomial contractions with additive identity 0) and padded
output rows/cols are sliced away.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_LANE = 128  # MXU/VPU lane width


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


def block_plan(dim: int, target: str, tpu_tile: int, cpu_cap: int) -> int:
    """Pick a block size for one dimension.

    ``tpu``: fixed MXU-aligned tile (clamped to the padded dim).
    ``cpu``: the whole dimension when it fits the cap -- grid collapses
    to one step and NO padding is introduced. (Perf iteration #1,
    EXPERIMENTS.md §Perf: the earlier plan rounded every dimension up
    to the 128 lane width, which pads a batch of 32 to 128 = 4x wasted
    work; interpret-mode copies made `outer_batch` 12x slower than
    necessary.)
    """
    if target == "tpu":
        return min(tpu_tile, _round_up(dim, _LANE))
    if dim <= cpu_cap:
        return dim
    # Near-even split: smallest grid whose block fits the cap, sized so
    # padding stays < one block (perf iteration #2: a hard cap padded
    # 1152 -> 2048 at 3c3d's fc1, 1.8x wasted work).
    steps = -(-dim // cpu_cap)
    return -(-dim // steps)


def _pad_axis(x, axis: int, to: int):
    pad = to - x.shape[axis]
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


# ---------------------------------------------------------------------------
# matmul_tn: out[b, a] = sum_n p[n, b] * q[n, a]
# ---------------------------------------------------------------------------


def _matmul_tn_kernel(p_ref, q_ref, o_ref):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        p_ref[...].T, q_ref[...], preferred_element_type=jnp.float32
    )


def matmul_tn_pallas(p, q, *, target: str = "cpu"):
    """``einsum('nb,na->ba', p, q)`` as a tiled, batch-reduced matmul.

    The reduction over the batch axis is the innermost grid dimension so
    the output tile accumulates in place (VMEM-resident on TPU).
    """
    n, b = p.shape
    n2, a = q.shape
    assert n == n2, (p.shape, q.shape)
    # cpu: one grid step for all reduction sizes we meet (perf iter #3:
    # 4 accumulation steps at the conv-patch reduction cost 5x vs one
    # fused dot; block memory at the cap is ~100 MB, well within RAM).
    bn = block_plan(n, target, 512, 262_144)
    bb = block_plan(b, target, _LANE, 4096)
    ba = block_plan(a, target, _LANE, 4096)
    np_, bp_ = _round_up(n, bn), _round_up(b, bb)
    ap_ = _round_up(a, ba)
    p = _pad_axis(_pad_axis(p, 0, np_), 1, bp_)
    q = _pad_axis(_pad_axis(q, 0, np_), 1, ap_)
    out = pl.pallas_call(
        _matmul_tn_kernel,
        grid=(bp_ // bb, ap_ // ba, np_ // bn),
        in_specs=[
            pl.BlockSpec((bn, bb), lambda i, j, k: (k, i)),
            pl.BlockSpec((bn, ba), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((bb, ba), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((bp_, ap_), jnp.float32),
        interpret=True,
    )(p, q)
    return out[:b, :a]


# ---------------------------------------------------------------------------
# outer_batch: out[n, b, a] = g[n, b] * x[n, a]   (per-sample gradients)
# ---------------------------------------------------------------------------


def _outer_batch_kernel(g_ref, x_ref, o_ref):
    o_ref[...] = g_ref[...][:, :, None] * x_ref[...][:, None, :]


def outer_batch_pallas(g, x, *, target: str = "cpu"):
    """``einsum('nb,na->nba', g, x)``: per-sample weight gradients.

    N leads the grid so every output tile is written exactly once -- the
    TPU-shaped replacement for the atomic-add scheme a CUDA port would
    use (DESIGN.md §Hardware-Adaptation).
    """
    n, b = g.shape
    n2, a = x.shape
    assert n == n2
    bn = block_plan(n, target, 8, 256)
    bb = block_plan(b, target, _LANE, 4096)
    ba = block_plan(a, target, _LANE, 4096)
    np_, bp_, ap_ = _round_up(n, bn), _round_up(b, bb), _round_up(a, ba)
    g = _pad_axis(_pad_axis(g, 0, np_), 1, bp_)
    x = _pad_axis(_pad_axis(x, 0, np_), 1, ap_)
    out = pl.pallas_call(
        _outer_batch_kernel,
        grid=(np_ // bn, bp_ // bb, ap_ // ba),
        in_specs=[
            pl.BlockSpec((bn, bb), lambda nn, i, j: (nn, i)),
            pl.BlockSpec((bn, ba), lambda nn, i, j: (nn, j)),
        ],
        out_specs=pl.BlockSpec((bn, bb, ba), lambda nn, i, j: (nn, i, j)),
        out_shape=jax.ShapeDtypeStruct((np_, bp_, ap_), jnp.float32),
        interpret=True,
    )(g, x)
    return out[:n, :b, :a]


# ---------------------------------------------------------------------------
# batch_l2: out[n] = (sum_a x[n,a]^2) * (sum_b g[n,b]^2)
# ---------------------------------------------------------------------------


def _batch_l2_kernel(g_ref, x_ref, o_ref):
    gsq = jnp.sum(g_ref[...] * g_ref[...], axis=1)
    xsq = jnp.sum(x_ref[...] * x_ref[...], axis=1)
    o_ref[...] = gsq * xsq


def batch_l2_pallas(g, x, *, target: str = "cpu"):
    """Fused individual-gradient L2 norms for a linear layer (Appx A.1).

    Exploits ``|g_n x_n^T|_F^2 = |g_n|^2 |x_n|^2`` -- never materializes
    the [N, B, A] per-sample gradients.
    """
    n, b = g.shape
    n2, a = x.shape
    assert n == n2
    bn = block_plan(n, target, 8, 256)
    np_ = _round_up(n, bn)
    g = _pad_axis(g, 0, np_)
    x = _pad_axis(x, 0, np_)
    out = pl.pallas_call(
        _batch_l2_kernel,
        grid=(np_ // bn,),
        in_specs=[
            pl.BlockSpec((bn, b), lambda i: (i, 0)),
            pl.BlockSpec((bn, a), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((bn,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((np_,), jnp.float32),
        interpret=True,
    )(g, x)
    return out[:n]


# ---------------------------------------------------------------------------
# sq_reduce: out[n, b] = sum_c s[n, b, c]^2   (diagonal extraction, Eq. 19)
# ---------------------------------------------------------------------------


def _sq_reduce_kernel(s_ref, o_ref):
    s = s_ref[...]
    o_ref[...] = jnp.sum(s * s, axis=2)


def sq_reduce_pallas(s, *, target: str = "cpu"):
    """Square-and-sum over the factorization columns of S [N, B, C]."""
    n, b, c = s.shape
    bn = block_plan(n, target, 8, 256)
    bb = block_plan(b, target, _LANE, 4096)
    np_, bp_ = _round_up(n, bn), _round_up(b, bb)
    s = _pad_axis(_pad_axis(s, 0, np_), 1, bp_)
    out = pl.pallas_call(
        _sq_reduce_kernel,
        grid=(np_ // bn, bp_ // bb),
        in_specs=[pl.BlockSpec((bn, bb, c), lambda i, j: (i, j, 0))],
        out_specs=pl.BlockSpec((bn, bb), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((np_, bp_), jnp.float32),
        interpret=True,
    )(s)
    return out[:n, :b]
