"""Layer-1 Pallas extraction kernels for BackPACK quantities.

The extraction hot spots of every BackPACK extension reduce to four
batched primitives (see DESIGN.md §2 and §6):

- :func:`outer_batch`      -- per-sample outer products  ``nb,na->nba``
                              (individual gradients of linear/unfolded-conv
                              layers, Eq. 5 / Fig. 4 of the paper);
- :func:`matmul_tn`        -- batch-reduced contraction  ``nb,na->ba``
                              (2nd moment, GGN diagonals, Kronecker
                              factors: all are squared/matmul reductions
                              over the batch, Appendix A.1/A.2);
- :func:`batch_l2`         -- fused per-sample squared-row-norm product
                              (individual-gradient L2 norms, Appendix A.1);
- :func:`sq_reduce`        -- fused square+sum over the factorization
                              columns of the backpropagated ``S`` matrices
                              (diagonal extraction, Eq. 19).

Each primitive has a Pallas implementation (``interpret=True`` -- the CPU
PJRT plugin cannot run Mosaic custom-calls) and a pure-jnp oracle in
:mod:`ref`. ``KERNEL_BACKEND`` selects which one is traced into the AOT
artifacts; block shapes come from ``pallas_impl.block_plan`` and depend on
the ``KERNEL_TARGET``:

- ``tpu``: MXU-shaped 128-aligned tiles sized for a 16 MB VMEM budget
  (the deployment plan documented in DESIGN.md §7);
- ``cpu``: maximal blocks to minimize interpret-mode grid steps (the
  benchmarking configuration used on this testbed).
"""

import os

from . import ref  # noqa: F401

#: "pallas" or "jnp" -- which implementation `ops.py` traces into graphs.
KERNEL_BACKEND = os.environ.get("BACKPACK_KERNELS", "pallas")

#: "cpu" (interpret-friendly maximal blocks) or "tpu" (VMEM-tile plan).
KERNEL_TARGET = os.environ.get("BACKPACK_KERNEL_TARGET", "cpu")


def use_pallas() -> bool:
    return KERNEL_BACKEND == "pallas"


from .pallas_impl import (  # noqa: E402,F401
    batch_l2_pallas,
    matmul_tn_pallas,
    outer_batch_pallas,
    sq_reduce_pallas,
)
from .ops import (  # noqa: E402,F401
    batch_l2,
    diag_ggn_from_sqrt,
    kron_factor_A,
    kron_factor_B,
    matmul_tn,
    outer_batch,
    sq_moment,
    sq_reduce,
)
